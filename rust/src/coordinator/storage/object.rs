//! [`ObjectBackend`] — an S3-style object store for the coordinator,
//! simulated over a local directory.
//!
//! The protocol layer sees **only object-store semantics**:
//!
//! * no rename and no mtime — publishing is an atomic whole-object PUT;
//! * claim-taking is a **conditional PUT** (`If-None-Match: *`): exactly
//!   one concurrent writer creates the key;
//! * the heartbeat is a **versioned metadata key** (`<key>.hb` holding
//!   `{version, millis}`), PUT on every touch; staleness is judged from
//!   its recorded wall-clock stamp, falling back to the object's
//!   `LastModified` before the first heartbeat;
//! * staged shard publication is **upload → complete → server-side copy
//!   → delete** instead of a rename;
//! * the ledger is scanned with **prefix LIST**, which may lag reality.
//!
//! Like any real object store (MinIO over ext4, S3 over its own
//! replicated storage), the simulator implements that API with local
//! primitives underneath; those internals (`.otmp.*` temps, `.hb`
//! sidecars) are invisible to the protocol — `list` filters them and
//! `delete` reaps sidecars with their object. Object *data* keys mirror
//! the POSIX file layout one-to-one (`docs/FORMATS.md`), so the bulk
//! formats are byte-identical across backends.
//!
//! # Fault injection
//!
//! [`ObjectFaults`] arms one-shot counters for the classic object-store
//! failure modes, so the cluster protocol can be tested adversarially
//! without AWS:
//!
//! * `put_races` — the next N conditional PUTs report
//!   [`CreateOutcome::AlreadyExists`] as if a concurrent writer won;
//! * `stale_reads` — the next N GETs see nothing (read-after-write lag);
//! * `list_ghosts` — the next N LISTs still contain recently deleted
//!   keys (listing lag).
//!
//! The CLI arms them from the `BNSL_OBJECT_FAULTS` environment variable
//! (`"put_races=2,stale_reads=1,list_ghosts=3"`); tests construct
//! [`ObjectBackend::with_faults`] directly. Every operation is also
//! counted ([`ObjectBackend::requests`]) — object backends are priced in
//! requests, not file descriptors ([`crate::coordinator::plan`]).

use super::posix::FileRandom;
use super::{BackendKind, CreateOutcome, KeyAge, RandomRead, ShardStream, StorageBackend};
use crate::telemetry;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeSet;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// Simulated multipart-upload part size: a shard stream of `b` bytes
/// costs `ceil(b / PART_BYTES)` part PUTs plus one completion request.
/// Shared with the analytic request pricing in
/// [`crate::coordinator::plan::sharded_plan`].
pub const PART_BYTES: u64 = 64 << 20;

/// Internal temp-name sequence (uploads, atomic PUTs, copies).
static OTMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Fresh internal temp path under `root` — the single point that
/// encodes the `.otmp.<pid>.<seq>` convention `is_internal` filters
/// and `sweep_internal` reaps. Used by uploads, atomic PUTs and
/// server-side copies alike.
fn otmp_path(root: &Path) -> PathBuf {
    root.join(format!(
        ".otmp.{}.{}",
        std::process::id(),
        OTMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// How many recently deleted keys the ghost ring remembers for the
/// `list_ghosts` fault.
const GHOST_RING: usize = 256;

/// One-shot fault counters (see the module docs). Each counter is
/// decremented as its fault fires; zero means "behave normally".
#[derive(Debug, Default)]
pub struct ObjectFaults {
    /// Conditional PUTs that spuriously lose their race.
    pub put_races: AtomicU64,
    /// GETs (reads/existence probes) that see nothing.
    pub stale_reads: AtomicU64,
    /// LISTs that still include recently deleted keys.
    pub list_ghosts: AtomicU64,
}

impl ObjectFaults {
    /// Parse the `BNSL_OBJECT_FAULTS` spec: comma-separated `name=count`.
    pub fn parse(spec: &str) -> Result<ObjectFaults> {
        let faults = ObjectFaults::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let Some((name, count)) = part.split_once('=') else {
                bail!("object fault '{part}' is not name=count");
            };
            let n: u64 = count
                .trim()
                .parse()
                .map_err(|_| anyhow!("object fault '{part}': count is not a number"))?;
            match name.trim() {
                "put_races" => faults.put_races.store(n, Ordering::Relaxed),
                "stale_reads" => faults.stale_reads.store(n, Ordering::Relaxed),
                "list_ghosts" => faults.list_ghosts.store(n, Ordering::Relaxed),
                other => bail!(
                    "unknown object fault '{other}' \
                     (known: put_races, stale_reads, list_ghosts)"
                ),
            }
        }
        Ok(faults)
    }

    /// Consume one shot of `counter`; true iff the fault fires.
    fn take(counter: &AtomicU64) -> bool {
        counter
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
            .is_ok()
    }
}

/// Request totals since the backend was opened — the object-store bill.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestTotals {
    pub puts: u64,
    pub gets: u64,
    pub lists: u64,
    pub deletes: u64,
    pub copies: u64,
}

/// One operation's request counter: the backend-local total behind
/// [`ObjectBackend::requests`] (what the planner's estimate is compared
/// against), mirrored live into the process-global
/// `bnsl_storage_requests_total{backend="object",op=...}` counter so a
/// scrape mid-run sees the bill as it accrues.
#[derive(Clone)]
struct Bill {
    local: Arc<AtomicU64>,
    global: telemetry::Counter,
}

impl Bill {
    fn new(op: &str) -> Bill {
        Bill {
            local: Arc::new(AtomicU64::new(0)),
            global: telemetry::storage_requests("object", op),
        }
    }

    #[inline]
    fn add(&self, n: u64) {
        self.local.fetch_add(n, Ordering::Relaxed);
        self.global.add(n);
    }

    #[inline]
    fn inc(&self) {
        self.add(1);
    }

    fn total(&self) -> u64 {
        self.local.load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for Bill {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.total())
    }
}

/// The object-store backend (see the module docs).
#[derive(Debug)]
pub struct ObjectBackend {
    root: PathBuf,
    faults: ObjectFaults,
    puts: Bill,
    gets: Bill,
    lists: Bill,
    deletes: Bill,
    copies: Bill,
    /// Ring of recently deleted keys — fodder for `list_ghosts`.
    recently_deleted: Mutex<Vec<String>>,
}

impl ObjectBackend {
    /// Open the store rooted at `root`, arming faults from the
    /// `BNSL_OBJECT_FAULTS` environment variable if set.
    pub fn open(root: &Path) -> Result<ObjectBackend> {
        let faults = match std::env::var("BNSL_OBJECT_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => ObjectFaults::parse(&spec)
                .with_context(|| format!("parsing BNSL_OBJECT_FAULTS='{spec}'"))?,
            _ => ObjectFaults::default(),
        };
        Ok(ObjectBackend::with_faults(root, faults))
    }

    /// Open the store with an explicit fault plan (test entry point).
    pub fn with_faults(root: &Path, faults: ObjectFaults) -> ObjectBackend {
        ObjectBackend {
            root: root.to_path_buf(),
            faults,
            puts: Bill::new("put"),
            gets: Bill::new("get"),
            lists: Bill::new("list"),
            deletes: Bill::new("delete"),
            copies: Bill::new("copy"),
            recently_deleted: Mutex::new(Vec::new()),
        }
    }

    /// The live fault counters — tests arm faults mid-scenario through
    /// this handle.
    pub fn faults(&self) -> &ObjectFaults {
        &self.faults
    }

    /// Request totals so far.
    pub fn requests(&self) -> RequestTotals {
        RequestTotals {
            puts: self.puts.total(),
            gets: self.gets.total(),
            lists: self.lists.total(),
            deletes: self.deletes.total(),
            copies: self.copies.total(),
        }
    }

    fn data_path(&self, key: &str) -> PathBuf {
        self.root.join(key)
    }

    fn hb_path(&self, key: &str) -> PathBuf {
        self.root.join(format!("{key}.hb"))
    }

    /// Simulator internals, filtered from listings and existence checks.
    fn is_internal(name: &str) -> bool {
        name.ends_with(".hb") || name.contains(".otmp.")
    }

    fn now_millis() -> u64 {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default()
            .as_millis() as u64
    }

    /// Durably write `body` to a fresh internal temp and return its
    /// path — the write half shared by atomic PUTs (which rename it)
    /// and conditional PUTs (which hard-link it).
    fn write_tmp_durable(&self, body: &[u8]) -> Result<PathBuf> {
        let tmp = otmp_path(&self.root);
        let mut file =
            File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        file.write_all(body)
            .with_context(|| format!("writing {}", tmp.display()))?;
        file.sync_all()
            .with_context(|| format!("syncing {}", tmp.display()))?;
        Ok(tmp)
    }

    /// Atomic whole-file write (the simulator's PUT primitive).
    fn write_atomic(&self, target: &Path, body: &[u8]) -> Result<()> {
        let tmp = self.write_tmp_durable(body)?;
        std::fs::rename(&tmp, target)
            .with_context(|| format!("storing object {}", target.display()))?;
        Ok(())
    }

    /// Current heartbeat version of `key` (0 before the first touch).
    fn hb_version(&self, key: &str) -> u64 {
        std::fs::read_to_string(self.hb_path(key))
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|doc| doc.get("version").and_then(Json::as_u64))
            .unwrap_or(0)
    }

    fn put_heartbeat(&self, key: &str, version: u64, millis: u64) {
        let body = Json::obj()
            .set("version", version)
            .set("millis", millis)
            .to_pretty();
        let _ = self.write_atomic(&self.hb_path(key), body.as_bytes());
    }

    fn remember_deleted(&self, key: &str) {
        let mut ghosts = self.recently_deleted.lock().unwrap();
        ghosts.push(key.to_string());
        if ghosts.len() > GHOST_RING {
            let excess = ghosts.len() - GHOST_RING;
            ghosts.drain(..excess);
        }
    }
}

impl StorageBackend for ObjectBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Object
    }

    fn reads_may_lag(&self) -> bool {
        // the read-after-write and listing lag this simulator injects
        // (`stale_reads`, `list_ghosts`) are real S3-class behaviors
        true
    }

    fn root(&self) -> String {
        self.root.display().to_string()
    }

    fn ensure_root(&self) -> Result<()> {
        std::fs::create_dir_all(&self.root)
            .with_context(|| format!("creating object root {}", self.root.display()))
    }

    fn create_exclusive(&self, key: &str, body: &[u8]) -> Result<CreateOutcome> {
        self.puts.inc();
        if ObjectFaults::take(&self.faults.put_races) {
            // injected lost race: the PUT is rejected as if a concurrent
            // writer created the key first
            return Ok(CreateOutcome::AlreadyExists);
        }
        let target = self.data_path(key);
        let tmp = self.write_tmp_durable(body)?;
        // If-None-Match: * — a hard link lands atomically iff the key is
        // absent, so exactly one concurrent conditional PUT succeeds and
        // readers never see a partial body
        let outcome = match std::fs::hard_link(&tmp, &target) {
            Ok(()) => Ok(CreateOutcome::Created),
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                Ok(CreateOutcome::AlreadyExists)
            }
            Err(e) => {
                Err(e).with_context(|| format!("conditional put {}", target.display()))
            }
        };
        let _ = std::fs::remove_file(&tmp);
        outcome
    }

    fn publish_doc(&self, key: &str, body: &[u8]) -> Result<()> {
        self.puts.inc();
        self.write_atomic(&self.data_path(key), body)
    }

    fn publish_doc_if_absent(&self, key: &str, body: &[u8]) -> Result<CreateOutcome> {
        // conditional PUTs are already atomic, durable and never
        // partial here — same primitive as claim creation
        self.create_exclusive(key, body)
    }

    fn put_doc(&self, key: &str, body: &[u8]) -> Result<()> {
        // objects are always whole-object atomic; there is no cheaper
        // non-atomic write to offer
        self.publish_doc(key, body)
    }

    fn read_doc(&self, key: &str) -> Result<Option<Vec<u8>>> {
        self.gets.inc();
        if ObjectFaults::take(&self.faults.stale_reads) {
            return Ok(None);
        }
        let path = self.data_path(key);
        match std::fs::read(&path) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e).with_context(|| format!("reading object {}", path.display())),
        }
    }

    fn exists(&self, key: &str) -> Result<bool> {
        self.gets.inc();
        if ObjectFaults::take(&self.faults.stale_reads) {
            return Ok(false);
        }
        Ok(self.data_path(key).exists())
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.deletes.inc();
        let path = self.data_path(key);
        match std::fs::remove_file(&path) {
            Ok(()) => self.remember_deleted(key),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e).with_context(|| format!("deleting object {}", path.display()))
            }
        }
        let _ = std::fs::remove_file(self.hb_path(key));
        Ok(())
    }

    fn touch(&self, key: &str) {
        // best-effort, like the POSIX mtime touch: never re-creates a
        // deleted key (the sidecar of a missing object is ignored by
        // liveness_age and reaped by sweep_internal)
        // existence probe (a HEAD on a real store) — billed like every
        // other read so requests() matches what a real bill would show
        self.gets.inc();
        if !self.data_path(key).exists() {
            return;
        }
        // one GET (reading the current heartbeat version) + one PUT
        self.gets.inc();
        self.puts.inc();
        let version = self.hb_version(key) + 1;
        self.put_heartbeat(key, version, Self::now_millis());
    }

    fn liveness_age(&self, key: &str) -> Option<KeyAge> {
        // a HEAD/GET of the heartbeat metadata — billed like any other
        // read, so `requests()` can be compared against the plan's
        // estimate without a wall-time-scaled blind spot
        self.gets.inc();
        let meta = std::fs::metadata(self.data_path(key)).ok()?;
        let stamp = std::fs::read_to_string(self.hb_path(key))
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|doc| doc.get("millis").and_then(Json::as_u64));
        match stamp {
            Some(millis) => {
                let now = Self::now_millis();
                Some(if now >= millis {
                    KeyAge::Past(Duration::from_millis(now - millis))
                } else {
                    KeyAge::Future(Duration::from_millis(millis - now))
                })
            }
            // no heartbeat yet: the object's LastModified stands in
            None => {
                let mtime = meta.modified().ok()?;
                Some(match mtime.elapsed() {
                    Ok(age) => KeyAge::Past(age),
                    Err(e) => KeyAge::Future(e.duration()),
                })
            }
        }
    }

    fn remove_contended(&self, key: &str, winner_tag: &str) -> Result<bool> {
        self.deletes.inc();
        // conditional delete: the simulator serialises contenders by
        // moving the object aside under a contender-unique name, so
        // exactly one delete succeeds
        let stolen = self.root.join(format!("{key}.{winner_tag}"));
        if std::fs::rename(self.data_path(key), &stolen).is_ok() {
            let _ = std::fs::remove_file(&stolen);
            let _ = std::fs::remove_file(self.hb_path(key));
            self.remember_deleted(key);
            Ok(true)
        } else {
            Ok(false)
        }
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        self.lists.inc();
        let mut names = BTreeSet::new();
        for entry in std::fs::read_dir(&self.root)
            .with_context(|| format!("listing {}", self.root.display()))?
        {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else {
                continue;
            };
            if Self::is_internal(name) {
                continue;
            }
            if name.starts_with(prefix) {
                names.insert(name.to_string());
            }
        }
        if ObjectFaults::take(&self.faults.list_ghosts) {
            // injected listing lag: recently deleted keys still appear
            for ghost in self.recently_deleted.lock().unwrap().iter() {
                if ghost.starts_with(prefix) {
                    names.insert(ghost.clone());
                }
            }
        }
        Ok(names.into_iter().collect())
    }

    fn sweep_internal(&self, older_than: Duration) {
        let Ok(entries) = std::fs::read_dir(&self.root) else {
            return;
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else {
                continue;
            };
            if name.contains(".otmp.") {
                // crashed uploads / atomic PUTs
                let old = entry
                    .metadata()
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|m| m.elapsed().ok())
                    .is_some_and(|age| age > older_than);
                if old {
                    let _ = std::fs::remove_file(entry.path());
                }
            } else if let Some(data) = name.strip_suffix(".hb") {
                // heartbeat sidecars orphaned by a crash between an
                // object delete and its sidecar delete
                if !self.data_path(data).exists() {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
    }

    fn create_stream(&self, key: &str, staged_tag: Option<&str>) -> Result<Box<dyn ShardStream>> {
        let upload = otmp_path(&self.root);
        let file = File::create(&upload)
            .with_context(|| format!("starting upload {}", upload.display()))?;
        Ok(Box::new(ObjectStream {
            w: BufWriter::new(file),
            upload,
            staged: staged_tag.map(|tag| self.data_path(&format!("{key}.{tag}"))),
            target: self.data_path(key),
            root: self.root.clone(),
            bytes: 0,
            puts: self.puts.clone(),
            copies: self.copies.clone(),
            deletes: self.deletes.clone(),
        }))
    }

    fn open_random(&self, key: &str) -> Result<Box<dyn RandomRead>> {
        self.gets.inc();
        Ok(Box::new(ObjectRandom {
            inner: FileRandom::open(self.data_path(key))?,
            gets: self.gets.clone(),
        }))
    }

    fn backdate(&self, key: &str, age: Duration) {
        let millis = Self::now_millis().saturating_sub(age.as_millis() as u64);
        self.put_heartbeat(key, self.hb_version(key), millis);
    }
}

/// One in-flight shard upload (see [`ObjectBackend`] docs).
struct ObjectStream {
    w: BufWriter<File>,
    /// The upload accumulates here (internal, invisible to LIST).
    upload: PathBuf,
    /// Staged object key the completed upload lands at (cluster path);
    /// `None` publishes the completed upload at `target` directly.
    staged: Option<PathBuf>,
    target: PathBuf,
    root: PathBuf,
    bytes: u64,
    puts: Bill,
    copies: Bill,
    deletes: Bill,
}

impl ShardStream for ObjectStream {
    fn write_all(&mut self, bytes: &[u8]) -> Result<()> {
        self.bytes += bytes.len() as u64;
        self.w
            .write_all(bytes)
            .with_context(|| format!("uploading to {}", self.upload.display()))
    }

    fn finish(mut self: Box<Self>) -> Result<()> {
        self.w
            .flush()
            .with_context(|| format!("flushing upload {}", self.upload.display()))?;
        self.w
            .get_ref()
            .sync_data()
            .with_context(|| format!("syncing upload {}", self.upload.display()))?;
        // bill the upload: one PUT per part + the completion request
        let parts = self.bytes.div_ceil(PART_BYTES).max(1);
        self.puts.add(parts + 1);
        match &self.staged {
            None => {
                // completing the upload IS the atomic publish
                std::fs::rename(&self.upload, &self.target).with_context(|| {
                    format!("completing upload of {}", self.target.display())
                })?;
            }
            Some(staged) => {
                // complete the upload at the staged key…
                std::fs::rename(&self.upload, staged).with_context(|| {
                    format!("completing staged upload {}", staged.display())
                })?;
                // …server-side copy it over the canonical key (atomic
                // whole-object replace, like any PUT)…
                self.copies.inc();
                let copy_tmp = otmp_path(&self.root);
                std::fs::copy(staged, &copy_tmp).with_context(|| {
                    format!("copying {} to {}", staged.display(), copy_tmp.display())
                })?;
                File::open(&copy_tmp)
                    .and_then(|f| f.sync_all())
                    .with_context(|| format!("syncing copy {}", copy_tmp.display()))?;
                std::fs::rename(&copy_tmp, &self.target).with_context(|| {
                    format!("publishing shard file {}", self.target.display())
                })?;
                // …and delete the staged upload
                self.deletes.inc();
                let _ = std::fs::remove_file(staged);
            }
        }
        Ok(())
    }
}

/// The shared [`FileRandom`] positioned reader, plus per-read request
/// billing (each window fetch is one ranged GET).
struct ObjectRandom {
    inner: FileRandom,
    gets: Bill,
}

impl RandomRead for ObjectRandom {
    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn read_exact_at(&mut self, offset: u64, out: &mut [u8]) -> Result<()> {
        // one ranged GET per window fetch
        self.gets.inc();
        self.inner.read_exact_at(offset, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(tag: &str, faults: ObjectFaults) -> (ObjectBackend, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "bnsl_object_backend_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let b = ObjectBackend::with_faults(&dir, faults);
        b.ensure_root().unwrap();
        (b, dir)
    }

    #[test]
    fn conditional_put_has_exactly_one_winner() {
        let (b, dir) = store("race", ObjectFaults::default());
        let wins: Vec<bool> = std::thread::scope(|scope| {
            let b = &b;
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    scope.spawn(move || {
                        let body = format!("{{\"host\": {i}}}");
                        matches!(
                            b.create_exclusive("claim-03-0001.json", body.as_bytes())
                                .unwrap(),
                            CreateOutcome::Created
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            wins.iter().filter(|&&w| w).count(),
            1,
            "exactly one of 8 conditional PUTs lands: {wins:?}"
        );
        // the winner's body is intact (never a mixture)
        let body = b.read_doc("claim-03-0001.json").unwrap().unwrap();
        let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(doc.get("host").and_then(Json::as_u64).is_some(), "{doc:?}");
        // no upload temps leaked
        let strays: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".otmp."))
            .collect();
        assert!(strays.is_empty(), "{strays:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lost_put_race_fault_fires_once_then_clears() {
        let (b, dir) = store("putrace", ObjectFaults::default());
        b.faults.put_races.store(1, Ordering::Relaxed);
        assert_eq!(
            b.create_exclusive("claim-00-0000.json", b"{}").unwrap(),
            CreateOutcome::AlreadyExists,
            "the injected race loss"
        );
        assert!(!b.data_path("claim-00-0000.json").exists(), "nothing landed");
        assert_eq!(
            b.create_exclusive("claim-00-0000.json", b"{}").unwrap(),
            CreateOutcome::Created,
            "the retry wins once the fault is spent"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_read_fault_masks_then_reveals() {
        let (b, dir) = store("stale", ObjectFaults::default());
        b.put_doc("done-02-0001.json", b"{\"x\": 1}").unwrap();
        b.faults.stale_reads.store(2, Ordering::Relaxed);
        assert_eq!(b.read_doc("done-02-0001.json").unwrap(), None, "lagged GET");
        assert!(!b.exists("done-02-0001.json").unwrap(), "lagged existence probe");
        assert_eq!(
            b.read_doc("done-02-0001.json").unwrap().unwrap(),
            b"{\"x\": 1}".to_vec(),
            "consistency restored after the lag window"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ghost_listing_shows_deleted_keys_until_lag_expires() {
        let (b, dir) = store("ghosts", ObjectFaults::default());
        b.put_doc("claim-05-0000.json", b"{}").unwrap();
        b.put_doc("claim-05-0001.json", b"{}").unwrap();
        b.delete("claim-05-0001.json").unwrap();
        assert_eq!(
            b.list("claim-05-").unwrap(),
            vec!["claim-05-0000.json".to_string()],
            "a consistent LIST omits the deleted key"
        );
        b.faults.list_ghosts.store(1, Ordering::Relaxed);
        assert_eq!(
            b.list("claim-05-").unwrap(),
            vec![
                "claim-05-0000.json".to_string(),
                "claim-05-0001.json".to_string()
            ],
            "the lagged LIST resurrects the deleted key as a ghost"
        );
        // the ghost is a listing artefact only: authoritative reads say gone
        assert!(!b.exists("claim-05-0001.json").unwrap());
        assert_eq!(
            b.list("claim-05-").unwrap(),
            vec!["claim-05-0000.json".to_string()],
            "LIST converges after the lag window"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn heartbeat_is_a_versioned_metadata_key() {
        let (b, dir) = store("hb", ObjectFaults::default());
        b.put_doc("claim-01-0000.json", b"{}").unwrap();
        // before the first touch, LastModified stands in
        match b.liveness_age("claim-01-0000.json") {
            Some(KeyAge::Past(age)) => assert!(age < Duration::from_secs(60), "{age:?}"),
            other => panic!("{other:?}"),
        }
        b.touch("claim-01-0000.json");
        assert_eq!(b.hb_version("claim-01-0000.json"), 1);
        b.touch("claim-01-0000.json");
        assert_eq!(b.hb_version("claim-01-0000.json"), 2, "version advances per touch");
        b.backdate("claim-01-0000.json", Duration::from_secs(3600));
        match b.liveness_age("claim-01-0000.json") {
            Some(KeyAge::Past(age)) => assert!(age >= Duration::from_secs(3000), "{age:?}"),
            other => panic!("{other:?}"),
        }
        b.touch("claim-01-0000.json");
        match b.liveness_age("claim-01-0000.json") {
            Some(KeyAge::Past(age)) => assert!(age < Duration::from_secs(60), "{age:?}"),
            other => panic!("{other:?}"),
        }
        // sidecars are internal: invisible to LIST, reaped with the object
        assert_eq!(
            b.list("claim-01-").unwrap(),
            vec!["claim-01-0000.json".to_string()]
        );
        b.delete("claim-01-0000.json").unwrap();
        assert!(!dir.join("claim-01-0000.json.hb").exists(), "sidecar reaped");
        // touching the deleted key does not resurrect anything
        b.touch("claim-01-0000.json");
        assert!(b.liveness_age("claim-01-0000.json").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn remove_contended_single_winner_reaps_sidecar() {
        let (b, dir) = store("steal", ObjectFaults::default());
        b.put_doc("claim-04-0002.json", b"{}").unwrap();
        b.touch("claim-04-0002.json");
        let wins: Vec<bool> = std::thread::scope(|scope| {
            let b = &b;
            let handles: Vec<_> = (0..6)
                .map(|i| {
                    scope.spawn(move || {
                        b.remove_contended("claim-04-0002.json", &format!("stale-{i}-9"))
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(wins.iter().filter(|&&w| w).count(), 1, "{wins:?}");
        assert!(!b.exists("claim-04-0002.json").unwrap());
        assert!(!dir.join("claim-04-0002.json.hb").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn staged_stream_publishes_by_copy_and_bills_requests() {
        let (b, dir) = store("copy", ObjectFaults::default());
        let before = b.requests();
        let mut w = b
            .create_stream("level_02_shard_0001.qr", Some("host-0003-77-0"))
            .unwrap();
        w.write_all(b"0123456789abcdef").unwrap();
        assert!(
            !b.exists("level_02_shard_0001.qr").unwrap(),
            "nothing canonical during the upload"
        );
        w.finish().unwrap();
        assert!(b.exists("level_02_shard_0001.qr").unwrap());
        assert!(
            !dir.join("level_02_shard_0001.qr.host-0003-77-0").exists(),
            "staged upload deleted after the copy"
        );
        let after = b.requests();
        assert_eq!(after.copies - before.copies, 1, "one server-side copy");
        assert!(after.deletes > before.deletes, "staged upload deletion billed");
        assert!(
            after.puts - before.puts >= 2,
            "part + completion PUTs billed: {after:?}"
        );
        // the published object reads back byte-exact, billing ranged GETs
        let mut r = b.open_random("level_02_shard_0001.qr").unwrap();
        assert_eq!(r.len(), 16);
        let mut buf = [0u8; 6];
        r.read_exact_at(10, &mut buf).unwrap();
        assert_eq!(&buf, b"abcdef");
        assert!(b.requests().gets > after.gets, "ranged GETs billed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unstaged_stream_completion_is_the_publish() {
        let (b, dir) = store("unstaged", ObjectFaults::default());
        let mut w = b.create_stream("level_00_shard_0000.qr", None).unwrap();
        w.write_all(b"xy").unwrap();
        assert!(!b.exists("level_00_shard_0000.qr").unwrap());
        w.finish().unwrap();
        assert_eq!(
            b.read_doc("level_00_shard_0000.qr").unwrap().unwrap(),
            b"xy".to_vec()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_reaps_orphan_sidecars_and_aged_temps() {
        let (b, dir) = store("sweep", ObjectFaults::default());
        b.put_doc("claim-00-0000.json", b"{}").unwrap();
        b.touch("claim-00-0000.json");
        // orphan sidecar: object gone, sidecar left (simulated crash)
        std::fs::write(dir.join("claim-09-0000.json.hb"), b"{}").unwrap();
        // aged internal temp vs fresh internal temp
        std::fs::write(dir.join(".otmp.1.0"), b"x").unwrap();
        let old = File::options()
            .write(true)
            .open(dir.join(".otmp.1.0"))
            .unwrap();
        old.set_modified(SystemTime::now() - Duration::from_secs(3600))
            .unwrap();
        drop(old);
        std::fs::write(dir.join(".otmp.1.1"), b"x").unwrap();
        b.sweep_internal(Duration::from_secs(60));
        assert!(!dir.join("claim-09-0000.json.hb").exists(), "orphan sidecar reaped");
        assert!(!dir.join(".otmp.1.0").exists(), "aged temp reaped");
        assert!(dir.join(".otmp.1.1").exists(), "fresh temp kept (may be live)");
        assert!(
            dir.join("claim-00-0000.json.hb").exists(),
            "live object's sidecar kept"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fault_spec_parses_and_rejects_garbage() {
        let f = ObjectFaults::parse("put_races=2, stale_reads=1,list_ghosts=3").unwrap();
        assert_eq!(f.put_races.load(Ordering::Relaxed), 2);
        assert_eq!(f.stale_reads.load(Ordering::Relaxed), 1);
        assert_eq!(f.list_ghosts.load(Ordering::Relaxed), 3);
        let f = ObjectFaults::parse("").unwrap();
        assert_eq!(f.put_races.load(Ordering::Relaxed), 0);
        assert!(ObjectFaults::parse("put_races").is_err());
        assert!(ObjectFaults::parse("put_races=x").is_err());
        let err = ObjectFaults::parse("drop_tables=1").unwrap_err().to_string();
        assert!(err.contains("drop_tables"), "{err}");
    }
}
