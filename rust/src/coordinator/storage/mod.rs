//! Pluggable durable-storage backends for the shard/cluster coordinator.
//!
//! Everything the coordinator persists — the manifest, the per-shard
//! frontier streams, and the cluster claim ledger — goes through one
//! [`StorageBackend`] trait whose operations are the **protocol steps**,
//! not raw filesystem calls:
//!
//! | operation | protocol step | POSIX | object store (S3-style) |
//! |---|---|---|---|
//! | [`create_exclusive`](StorageBackend::create_exclusive) | claim / init-lock take | `O_CREAT\|O_EXCL` | conditional PUT (`If-None-Match: *`) |
//! | [`touch`](StorageBackend::touch) | heartbeat | mtime touch | versioned heartbeat metadata key |
//! | [`liveness_age`](StorageBackend::liveness_age) | staleness check | `stat` mtime | heartbeat stamp, else object `LastModified` |
//! | [`remove_contended`](StorageBackend::remove_contended) | stale-claim steal | rename-to-unique, then unlink | conditional delete (one remover wins) |
//! | [`publish_doc`](StorageBackend::publish_doc) | manifest / done-marker commit | write-temp + fsync + rename + dir fsync | atomic whole-object PUT |
//! | [`create_stream`](StorageBackend::create_stream) | shard frontier write | (staged) file + fsync + rename | staged upload → complete → server-side copy → delete |
//! | [`open_random`](StorageBackend::open_random) | windowed shard reads | `seek` + `read` | ranged GET per window |
//! | [`list`](StorageBackend::list) | ledger scan / cleanup | `readdir` | prefix LIST (may lag — deletes are idempotent) |
//!
//! Two implementations ship:
//!
//! * [`PosixBackend`] — today's behavior, byte for byte: same file
//!   names, same temp-file naming, same fsync points. The default.
//! * [`ObjectBackend`] — an object-store **simulator** rooted in a local
//!   directory. The *protocol layer* sees only S3 semantics (no rename,
//!   no mtime, conditional PUT, prefix listing), while the simulator
//!   implements them with local primitives — exactly how a real object
//!   store implements its API over its own storage. It injects faults
//!   (lost PUT races, stale reads, listing lag) so the whole cluster
//!   protocol is adversarially testable without AWS, and it counts
//!   requests so [`crate::coordinator::plan`]'s request pricing can be
//!   checked against reality.
//!
//! Keys are flat names relative to the run root and mirror the POSIX
//! file layout one-to-one (`manifest.json`, `level_03_shard_0001.qr`,
//! `claim-03-0001.json`, …) — see `docs/FORMATS.md`.
//!
//! The repo's core invariant makes backend bugs *survivable* rather
//! than corrupting: every execution mode of the sweep is bit-identical,
//! so a duplicated shard computation (after a spurious steal, a lost
//! PUT, a ghost listing entry) republishes the same bytes.

pub mod object;
pub mod posix;

pub use object::{ObjectBackend, ObjectFaults, RequestTotals};
pub use posix::PosixBackend;

use anyhow::Result;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

/// Which backend a run coordinates through (CLI `--backend`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Shared POSIX filesystem (local disk, NFSv4) — the default.
    #[default]
    Posix,
    /// S3-style object store (simulated locally; see [`ObjectBackend`]).
    Object,
}

impl BackendKind {
    /// Parse a CLI `--backend` value.
    pub fn parse(name: &str) -> Option<BackendKind> {
        match name {
            "posix" => Some(BackendKind::Posix),
            "object" => Some(BackendKind::Object),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Posix => "posix",
            BackendKind::Object => "object",
        }
    }
}

/// Outcome of a conditional create ([`StorageBackend::create_exclusive`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CreateOutcome {
    /// This caller created the key — it owns whatever the key locks.
    Created,
    /// The key already exists (or a concurrent writer won the race).
    AlreadyExists,
}

/// Observed age of a key's liveness stamp, relative to the observer's
/// clock. Stamps can sit in the observer's *future* under clock skew;
/// callers decide how much future-ness still counts as fresh.
#[derive(Clone, Copy, Debug)]
pub enum KeyAge {
    /// Stamp is `d` in the past (the common case).
    Past(Duration),
    /// Stamp is `d` in the observer's future (clock skew).
    Future(Duration),
}

/// Shared handle on one backend — cloned freely across worker threads.
pub type SharedBackend = Arc<dyn StorageBackend>;

/// One durable-storage backend for a coordinator run.
///
/// Implementations must be safe to share across threads (each `bnsl`
/// host's worker pool holds one handle) and across *processes* via the
/// storage itself: all coordination state lives behind the trait, never
/// in the handle.
pub trait StorageBackend: Send + Sync + std::fmt::Debug {
    fn kind(&self) -> BackendKind;

    /// Whether reads (GET / existence probes / LIST) may transiently
    /// *lag* writes on this backend (read-after-write windows, listing
    /// lag). `false` promises strong consistency (POSIX); when `true`,
    /// callers on fatal paths retry within a bounded grace window
    /// instead of trusting one unlucky read.
    fn reads_may_lag(&self) -> bool;

    /// Human-readable root (path or bucket prefix) for error messages.
    fn root(&self) -> String;

    /// Create the root if it does not exist (idempotent).
    fn ensure_root(&self) -> Result<()>;

    /// Atomic create-if-absent of a small document. Exactly one of any
    /// set of concurrent callers observes [`CreateOutcome::Created`].
    fn create_exclusive(&self, key: &str, body: &[u8]) -> Result<CreateOutcome>;

    /// Durably publish a small document: readers see the old bytes or
    /// the new bytes, never a mixture, and the new bytes survive a
    /// crash once this returns.
    fn publish_doc(&self, key: &str, body: &[u8]) -> Result<()>;

    /// Conditional durable publish: the atomicity and durability of
    /// [`publish_doc`](StorageBackend::publish_doc) but landing only if
    /// `key` is absent — exactly one of any set of concurrent callers
    /// creates it, and an existing document is **never replaced**. The
    /// initial-manifest primitive: a creator whose existence probe
    /// lagged (read-after-write) must not be able to overwrite a
    /// committed run's manifest with a fresh one.
    fn publish_doc_if_absent(&self, key: &str, body: &[u8]) -> Result<CreateOutcome>;

    /// Plain overwrite of a small document (idempotent markers whose
    /// loss is harmless — they are re-announced).
    fn put_doc(&self, key: &str, body: &[u8]) -> Result<()>;

    /// Read a whole small document; `None` if the key does not exist.
    fn read_doc(&self, key: &str) -> Result<Option<Vec<u8>>>;

    fn exists(&self, key: &str) -> Result<bool>;

    /// Idempotent delete (absent keys are not an error).
    fn delete(&self, key: &str) -> Result<()>;

    /// Refresh the key's liveness stamp without touching its content.
    /// Best-effort: a failed touch only delays freshness, so errors are
    /// swallowed (the stale window is generous by design).
    fn touch(&self, key: &str);

    /// Age of the key's liveness stamp; `None` when the key is gone or
    /// its metadata is unreadable.
    fn liveness_age(&self, key: &str) -> Option<KeyAge>;

    /// Remove `key` such that **exactly one** concurrent caller returns
    /// `true` — the stale-steal primitive. `winner_tag` must be unique
    /// per contender (host + pid). Note the inherent ABA window shared
    /// by both backends: a contender acting on an old staleness
    /// observation can remove a freshly re-created key; the protocol
    /// tolerates this because duplicated shard work is deterministic.
    fn remove_contended(&self, key: &str, winner_tag: &str) -> Result<bool>;

    /// Keys starting with `prefix`, sorted. May lag reality on backends
    /// with eventually-consistent listings — callers must treat entries
    /// as hints (deletes are idempotent, authoritative state is read
    /// with [`read_doc`](StorageBackend::read_doc)/[`exists`](StorageBackend::exists)).
    fn list(&self, prefix: &str) -> Result<Vec<String>>;

    /// Best-effort sweep of the backend's *internal* leftovers (crashed
    /// writers' temp files, orphaned metadata) older than `older_than`.
    fn sweep_internal(&self, older_than: Duration);

    /// Open a sequential bulk writer for a shard stream. With a
    /// `staged_tag` the data is published under `key` only at
    /// [`ShardStream::finish`]; until then it is invisible under `key`
    /// (POSIX: `key.tag` temp file renamed into place; object: staged
    /// upload completed at `key.tag`, then server-side copied to `key`).
    fn create_stream(&self, key: &str, staged_tag: Option<&str>) -> Result<Box<dyn ShardStream>>;

    /// Open a committed, immutable bulk object for random-access reads.
    fn open_random(&self, key: &str) -> Result<Box<dyn RandomRead>>;

    /// Rewind a key's liveness stamp by `age` — the fault-injection /
    /// ops hook behind the stale-claim tests ("pretend this host died
    /// `age` ago"). Best-effort, like [`touch`](StorageBackend::touch).
    fn backdate(&self, key: &str, age: Duration);
}

/// Sequential writer for one bulk shard stream.
pub trait ShardStream: Send {
    fn write_all(&mut self, bytes: &[u8]) -> Result<()>;

    /// Flush, make durable, and (for staged writers) atomically publish
    /// under the canonical key. Nothing is published if this errors.
    fn finish(self: Box<Self>) -> Result<()>;
}

/// Random-access reader over one committed bulk object.
pub trait RandomRead: Send {
    /// Total object length in bytes.
    fn len(&self) -> u64;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fill `out` from `offset` (a ranged GET / positioned read).
    fn read_exact_at(&mut self, offset: u64, out: &mut [u8]) -> Result<()>;
}

/// Construct the backend selected by `kind`, rooted at `root`.
/// [`ObjectBackend`] additionally reads its fault-injection config from
/// the `BNSL_OBJECT_FAULTS` environment variable (see [`ObjectFaults`]).
pub fn make_backend(kind: BackendKind, root: &Path) -> Result<SharedBackend> {
    Ok(match kind {
        BackendKind::Posix => Arc::new(PosixBackend::new(root)),
        BackendKind::Object => Arc::new(ObjectBackend::open(root)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_cli_names() {
        assert_eq!(BackendKind::parse("posix"), Some(BackendKind::Posix));
        assert_eq!(BackendKind::parse("object"), Some(BackendKind::Object));
        assert_eq!(BackendKind::parse("s3"), None);
        assert_eq!(BackendKind::Posix.name(), "posix");
        assert_eq!(BackendKind::Object.name(), "object");
        assert_eq!(BackendKind::default(), BackendKind::Posix);
    }

    #[test]
    fn make_backend_dispatches_on_kind() {
        let dir = std::env::temp_dir().join(format!("bnsl_mkbackend_{}", std::process::id()));
        let posix = make_backend(BackendKind::Posix, &dir).unwrap();
        assert_eq!(posix.kind(), BackendKind::Posix);
        let object = make_backend(BackendKind::Object, &dir).unwrap();
        assert_eq!(object.kind(), BackendKind::Object);
        assert_eq!(posix.root(), object.root());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
