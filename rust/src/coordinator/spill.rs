//! Disk-backed frontier level — the paper's §5.3 extension.
//!
//! At a *peak* level (where `k·C(p,k)` is near its maximum) the
//! best-parent-set vectors dominate memory. This store writes them to a
//! temporary file right after the level is computed and serves the next
//! level's random-access reads through a direct-mapped window cache. The
//! subset scores `q`/`r` (16 bytes per subset — the non-dominant part)
//! stay in RAM, mirroring the paper's "store the optimal parent set
//! vector of one level on disk".
//!
//! Colex locality makes the cache effective: the drop-one ranks of
//! consecutively enumerated masks are themselves nearly consecutive, so
//! most reads hit a recently loaded window.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

/// Entries per cache window (12 bytes each → 48 KiB windows).
const WINDOW: usize = 4096;
/// Direct-mapped cache slots (64 windows → 3 MiB resident).
const SLOTS: usize = 64;

/// Record layout on disk: little-endian f64 score + u32 mask, 12 bytes.
const RECORD: usize = 12;

/// A frontier level whose `bps`/`bpm` arrays live on disk.
pub struct SpilledLevel {
    pub k: usize,
    /// `log Q` per subset (RAM)
    pub q: Vec<f64>,
    /// `log R` per subset (RAM)
    pub r: Vec<f64>,
    entries: usize,
    file: RefCell<File>,
    cache: RefCell<WindowCache>,
    bytes_on_disk: u64,
    hits: std::cell::Cell<u64>,
    misses: std::cell::Cell<u64>,
}

struct WindowCache {
    /// which window each slot holds (-1 = empty)
    tags: Vec<i64>,
    /// slot data, SLOTS × WINDOW records
    data: Vec<u8>,
}

/// Incremental writer: the level sweep appends each batch's parent-set
/// records as they are computed, so the full `bps`/`bpm` arrays of a
/// spilled level never exist in RAM at once (the paper's §5.3 point —
/// the in-flight level holds only its `q`/`r` plus one batch of records).
pub struct SpilledLevelWriter {
    k: usize,
    file: File,
    buf: Vec<u8>,
    entries: usize,
}

impl SpilledLevelWriter {
    /// Open the spill file for level `k` in `dir`.
    pub fn create(dir: &Path, k: usize) -> Result<SpilledLevelWriter> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("bnsl_spill_level_{k}.bin"));
        let file = File::options()
            .create(true)
            .truncate(true)
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("creating spill file {}", path.display()))?;
        // unlink immediately: the open handle keeps the data readable and
        // the file vanishes automatically on drop/crash (POSIX).
        let _ = std::fs::remove_file(&path);
        Ok(SpilledLevelWriter {
            k,
            file,
            buf: Vec::with_capacity(WINDOW * RECORD),
            entries: 0,
        })
    }

    /// Append one computed batch of records.
    pub fn append(&mut self, bps: &[f64], bpm: &[u32]) -> Result<()> {
        assert_eq!(bps.len(), bpm.len());
        self.buf.clear();
        for i in 0..bps.len() {
            self.buf.extend_from_slice(&bps[i].to_le_bytes());
            self.buf.extend_from_slice(&bpm[i].to_le_bytes());
        }
        self.file.write_all(&self.buf)?;
        self.entries += bps.len();
        Ok(())
    }

    /// Seal the file and attach the level's in-RAM scores.
    pub fn finish(mut self, q: Vec<f64>, r: Vec<f64>) -> Result<SpilledLevel> {
        self.file.flush()?;
        Ok(SpilledLevel {
            k: self.k,
            q,
            r,
            entries: self.entries,
            bytes_on_disk: (self.entries * RECORD) as u64,
            file: RefCell::new(self.file),
            cache: RefCell::new(WindowCache {
                tags: vec![-1; SLOTS],
                data: vec![0; SLOTS * WINDOW * RECORD],
            }),
            hits: std::cell::Cell::new(0),
            misses: std::cell::Cell::new(0),
        })
    }
}

impl SpilledLevel {
    /// Write a fully-materialised level's parent-set vectors to `dir` and
    /// return the disk-backed frontier (bulk path; the solver prefers the
    /// incremental [`SpilledLevelWriter`]).
    pub fn write(
        dir: &Path,
        k: usize,
        q: Vec<f64>,
        r: Vec<f64>,
        bps: &[f64],
        bpm: &[u32],
    ) -> Result<SpilledLevel> {
        let mut writer = SpilledLevelWriter::create(dir, k)?;
        let mut off = 0usize;
        while off < bps.len() {
            let take = WINDOW.min(bps.len() - off);
            writer.append(&bps[off..off + take], &bpm[off..off + take])?;
            off += take;
        }
        writer.finish(q, r)
    }

    /// Bytes written to disk.
    pub fn bytes_on_disk(&self) -> u64 {
        self.bytes_on_disk
    }

    /// Resident bytes (q + r + cache), for the memory accounting.
    pub fn resident_bytes(&self) -> usize {
        self.q.len() * 16 + SLOTS * WINDOW * RECORD + SLOTS * 8
    }

    /// (cache hits, cache misses) so far.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }

    /// Read record `idx` (= `t*k + pos`).
    #[inline]
    pub fn read(&self, idx: usize) -> (f64, u32) {
        debug_assert!(idx < self.entries);
        let window = idx / WINDOW;
        let within = idx % WINDOW;
        let slot = window % SLOTS;
        let mut cache = self.cache.borrow_mut();
        if cache.tags[slot] != window as i64 {
            self.misses.set(self.misses.get() + 1);
            let start = window * WINDOW;
            let len = WINDOW.min(self.entries - start);
            let mut file = self.file.borrow_mut();
            file.seek(SeekFrom::Start((start * RECORD) as u64))
                .expect("spill seek");
            let base = slot * WINDOW * RECORD;
            file.read_exact(&mut cache.data[base..base + len * RECORD])
                .expect("spill read");
            cache.tags[slot] = window as i64;
        } else {
            self.hits.set(self.hits.get() + 1);
        }
        let off = slot * WINDOW * RECORD + within * RECORD;
        let score = f64::from_le_bytes(cache.data[off..off + 8].try_into().unwrap());
        let mask = u32::from_le_bytes(cache.data[off + 8..off + 12].try_into().unwrap());
        (score, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("bnsl_spill_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrips_all_records() {
        let n = 3 * WINDOW + 17; // exercise a partial tail window
        let bps: Vec<f64> = (0..n).map(|i| i as f64 * 0.5 - 100.0).collect();
        let bpm: Vec<u32> = (0..n).map(|i| (i * 7) as u32).collect();
        let lvl = SpilledLevel::write(&tmpdir(), 3, vec![0.0; 4], vec![0.0; 4], &bps, &bpm)
            .unwrap();
        for i in 0..n {
            let (s, m) = lvl.read(i);
            assert_eq!(s, bps[i], "record {i}");
            assert_eq!(m, bpm[i]);
        }
        assert_eq!(lvl.bytes_on_disk(), (n * RECORD) as u64);
    }

    #[test]
    fn random_access_pattern_is_correct_under_thrashing() {
        // more windows than slots → forced evictions
        let n = (SLOTS + 8) * WINDOW;
        let bps: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let bpm: Vec<u32> = (0..n).map(|i| i as u32).collect();
        let lvl =
            SpilledLevel::write(&tmpdir(), 5, Vec::new(), Vec::new(), &bps, &bpm).unwrap();
        let mut state = 0x1234_5678_u64;
        for _ in 0..50_000 {
            state = crate::util::rng::splitmix64(&mut state);
            let i = (state % n as u64) as usize;
            let (s, m) = lvl.read(i);
            assert_eq!(m, i as u32);
            assert_eq!(s, bps[i]);
        }
        let (hits, misses) = lvl.cache_stats();
        assert!(misses > 0, "thrashing expected");
        assert_eq!(hits + misses, 50_000);
    }

    #[test]
    fn sequential_reads_mostly_hit() {
        let n = 4 * WINDOW;
        let bps = vec![1.5f64; n];
        let bpm = vec![9u32; n];
        let lvl =
            SpilledLevel::write(&tmpdir(), 2, Vec::new(), Vec::new(), &bps, &bpm).unwrap();
        for i in 0..n {
            let _ = lvl.read(i);
        }
        let (hits, misses) = lvl.cache_stats();
        assert_eq!(misses, 4, "one miss per window");
        assert_eq!(hits, (n - 4) as u64);
    }

    #[test]
    fn resident_bytes_are_bounded_by_cache_not_level() {
        let n = SLOTS * 10 * WINDOW; // 640 windows on disk (~30 MiB)
        let lvl = SpilledLevel::write(
            &tmpdir(),
            7,
            vec![0.0; 10],
            vec![0.0; 10],
            &vec![0.0; n],
            &vec![0; n],
        )
        .unwrap();
        // resident = q/r + the fixed window cache, far below the level
        assert!(lvl.resident_bytes() < n * RECORD / 8);
    }
}
