//! Disk-backed frontier level — the paper's §5.3 extension.
//!
//! At a *peak* level (where `k·C(p,k)` is near its maximum) the
//! best-parent-set vectors dominate memory. This store writes them to a
//! temporary file right after the level is computed and serves the next
//! level's random-access reads through a direct-mapped window cache. The
//! subset scores `q`/`r` (16 bytes per subset — the non-dominant part)
//! stay in RAM, mirroring the paper's "store the optimal parent set
//! vector of one level on disk".
//!
//! # On-disk format (v1)
//!
//! A 16-byte header — magic `b"BNSLSPIL"`, format-version byte, mask-width
//! byte (4 = `u32`, 8 = `u64`), level `k`, record-kind byte, 4 reserved
//! bytes — followed by fixed-size records: little-endian `f64` best score
//! + the argmax parent mask at the tagged width. Records are therefore
//! 12 bytes on the narrow path (unchanged from the untagged seed layout)
//! and 16 bytes on the wide path; a reader always validates
//! magic/version/width/kind before trusting offsets, so mixing widths or
//! record kinds across files is caught immediately.
//!
//! The same header (with different kind bytes) fronts the sharded
//! coordinator's `.bps`/`.qr`/`.sink` files — see
//! [`crate::coordinator::shard`] — and the full byte-level specification,
//! including a worked hex example, lives in
//! [`docs/FORMATS.md`](https://github.com/paper-repo-growth/bnsl/blob/main/docs/FORMATS.md)
//! (in-tree: `docs/FORMATS.md`).
//!
//! Colex locality makes the cache effective: the drop-one ranks of
//! consecutively enumerated masks are themselves nearly consecutive, so
//! most reads hit a recently loaded window.

use crate::bitset::VarMask;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::marker::PhantomData;
use std::path::Path;

/// Entries per cache window (48 KiB windows narrow / 64 KiB wide).
/// Shared with the sharded readers in [`crate::coordinator::shard`].
pub(crate) const WINDOW: usize = 4096;
/// Direct-mapped cache slots (64 windows → 3–4 MiB resident; the
/// sharded readers divide this budget across a level's shards).
pub(crate) const SLOTS: usize = 64;

/// Spill-file magic.
pub(crate) const MAGIC: &[u8; 8] = b"BNSLSPIL";
/// Current format version.
pub(crate) const VERSION: u8 = 1;
/// Header bytes: magic(8) + version(1) + mask width(1) + k(1) + kind(1)
/// + reserved(4).
pub(crate) const HEADER: usize = 16;

/// Record kinds stored in header byte 11 (see `docs/FORMATS.md`).
/// `KIND_BPS` is 0 so pre-shard spill files (which zero-filled the
/// reserved bytes) remain readable.
pub(crate) const KIND_BPS: u8 = 0;
/// `q`/`r` subset scores: two little-endian `f64`s per record.
pub(crate) const KIND_QR: u8 = 1;
/// Sink records: sink variable byte + parent mask per record.
pub(crate) const KIND_SINK: u8 = 2;
/// Prune-presence records (`.prn` sidecars of prune-format sharded
/// runs): one 520-byte block record per 4096 colex ranks — a little-
/// endian `u64` count of surviving subsets *before* the block, then a
/// 512-byte presence bitmap (bit set = the rank's records were emitted).
pub(crate) const KIND_PRN: u8 = 3;

/// Bytes per record at width `M`: little-endian f64 score + mask.
#[inline]
pub(crate) const fn record_bytes<M: VarMask>() -> usize {
    8 + M::BYTES
}

/// Build the 16-byte v1 header for a file of `kind` records at level `k`
/// over masks of `width_bytes`.
pub(crate) fn encode_header(width_bytes: u8, k: u8, kind: u8) -> [u8; HEADER] {
    let mut header = [0u8; HEADER];
    header[..8].copy_from_slice(MAGIC);
    header[8] = VERSION;
    header[9] = width_bytes;
    header[10] = k;
    header[11] = kind;
    header
}

/// Validate a v1 header against the expected width/level/kind. `name` is
/// the file (path) the error message should blame — resume diagnostics
/// depend on it.
pub(crate) fn decode_header(
    header: &[u8; HEADER],
    expect_width: usize,
    expect_k: usize,
    expect_kind: u8,
    name: &str,
) -> Result<()> {
    if &header[..8] != MAGIC {
        bail!("{name}: spill file header corrupt (bad magic)");
    }
    if header[8] != VERSION {
        bail!(
            "{name}: spill file format v{} unsupported (reader is v{VERSION})",
            header[8]
        );
    }
    if header[9] as usize != expect_width {
        bail!(
            "{name}: spill file mask width {} bytes does not match reader width {} bytes",
            header[9],
            expect_width
        );
    }
    if header[10] as usize != expect_k {
        bail!(
            "{name}: spill file is for level {} but the reader expected level {}",
            header[10],
            expect_k
        );
    }
    if header[11] != expect_kind {
        bail!(
            "{name}: spill file holds record kind {} but the reader expected kind {}",
            header[11],
            expect_kind
        );
    }
    Ok(())
}

/// A frontier level whose `bps`/`bpm` arrays live on disk (masks of
/// width `M`).
pub struct SpilledLevel<M: VarMask> {
    pub k: usize,
    /// `log Q` per subset (RAM)
    pub q: Vec<f64>,
    /// `log R` per subset (RAM)
    pub r: Vec<f64>,
    entries: usize,
    file: RefCell<File>,
    cache: RefCell<WindowCache>,
    bytes_on_disk: u64,
    hits: std::cell::Cell<u64>,
    misses: std::cell::Cell<u64>,
    _width: PhantomData<M>,
}

struct WindowCache {
    /// which window each slot holds (-1 = empty)
    tags: Vec<i64>,
    /// slot data, SLOTS × WINDOW records
    data: Vec<u8>,
}

/// Incremental writer: the level sweep appends each batch's parent-set
/// records as they are computed, so the full `bps`/`bpm` arrays of a
/// spilled level never exist in RAM at once (the paper's §5.3 point —
/// the in-flight level holds only its `q`/`r` plus one batch of records).
pub struct SpilledLevelWriter<M: VarMask> {
    k: usize,
    file: File,
    buf: Vec<u8>,
    entries: usize,
    _width: PhantomData<M>,
}

impl<M: VarMask> SpilledLevelWriter<M> {
    /// Open the spill file for level `k` in `dir` and write the v1
    /// header (version + mask-width tag).
    pub fn create(dir: &Path, k: usize) -> Result<SpilledLevelWriter<M>> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("bnsl_spill_level_{k}.bin"));
        let mut file = File::options()
            .create(true)
            .truncate(true)
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("creating spill file {}", path.display()))?;
        // unlink immediately: the open handle keeps the data readable and
        // the file vanishes automatically on drop/crash (POSIX).
        let _ = std::fs::remove_file(&path);
        file.write_all(&encode_header(M::BYTES as u8, k as u8, KIND_BPS))?;
        Ok(SpilledLevelWriter {
            k,
            file,
            buf: Vec::with_capacity(WINDOW * record_bytes::<M>()),
            entries: 0,
            _width: PhantomData,
        })
    }

    /// Append one computed batch of records.
    pub fn append(&mut self, bps: &[f64], bpm: &[M]) -> Result<()> {
        assert_eq!(bps.len(), bpm.len());
        self.buf.clear();
        for i in 0..bps.len() {
            self.buf.extend_from_slice(&bps[i].to_le_bytes());
            self.buf
                .extend_from_slice(&bpm[i].to_u64().to_le_bytes()[..M::BYTES]);
        }
        self.file.write_all(&self.buf)?;
        self.entries += bps.len();
        Ok(())
    }

    /// Seal the file, re-validate its header, and attach the level's
    /// in-RAM scores.
    pub fn finish(mut self, q: Vec<f64>, r: Vec<f64>) -> Result<SpilledLevel<M>> {
        self.file.flush()?;
        // Re-read and validate the header before serving reads: a wrong
        // width or version here means every record offset would be junk.
        self.file.seek(SeekFrom::Start(0))?;
        let mut header = [0u8; HEADER];
        self.file.read_exact(&mut header)?;
        decode_header(
            &header,
            M::BYTES,
            self.k,
            KIND_BPS,
            &format!("spill level {}", self.k),
        )?;
        Ok(SpilledLevel {
            k: self.k,
            q,
            r,
            entries: self.entries,
            bytes_on_disk: (HEADER + self.entries * record_bytes::<M>()) as u64,
            file: RefCell::new(self.file),
            cache: RefCell::new(WindowCache {
                tags: vec![-1; SLOTS],
                data: vec![0; SLOTS * WINDOW * record_bytes::<M>()],
            }),
            hits: std::cell::Cell::new(0),
            misses: std::cell::Cell::new(0),
            _width: PhantomData,
        })
    }
}

impl<M: VarMask> SpilledLevel<M> {
    /// Write a fully-materialised level's parent-set vectors to `dir` and
    /// return the disk-backed frontier (bulk path; the solver prefers the
    /// incremental [`SpilledLevelWriter`]).
    pub fn write(
        dir: &Path,
        k: usize,
        q: Vec<f64>,
        r: Vec<f64>,
        bps: &[f64],
        bpm: &[M],
    ) -> Result<SpilledLevel<M>> {
        let mut writer = SpilledLevelWriter::create(dir, k)?;
        let mut off = 0usize;
        while off < bps.len() {
            let take = WINDOW.min(bps.len() - off);
            writer.append(&bps[off..off + take], &bpm[off..off + take])?;
            off += take;
        }
        writer.finish(q, r)
    }

    /// Bytes written to disk (header + records).
    pub fn bytes_on_disk(&self) -> u64 {
        self.bytes_on_disk
    }

    /// Resident bytes (q + r + cache), for the memory accounting.
    pub fn resident_bytes(&self) -> usize {
        self.q.len() * 16 + SLOTS * WINDOW * record_bytes::<M>() + SLOTS * 8
    }

    /// (cache hits, cache misses) so far.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }

    /// Read record `idx` (= `t*k + pos`).
    #[inline]
    pub fn read(&self, idx: usize) -> (f64, M) {
        debug_assert!(idx < self.entries);
        let record = record_bytes::<M>();
        let window = idx / WINDOW;
        let within = idx % WINDOW;
        let slot = window % SLOTS;
        let mut cache = self.cache.borrow_mut();
        if cache.tags[slot] != window as i64 {
            self.misses.set(self.misses.get() + 1);
            let start = window * WINDOW;
            let len = WINDOW.min(self.entries - start);
            let mut file = self.file.borrow_mut();
            file.seek(SeekFrom::Start((HEADER + start * record) as u64))
                .expect("spill seek");
            let base = slot * WINDOW * record;
            file.read_exact(&mut cache.data[base..base + len * record])
                .expect("spill read");
            cache.tags[slot] = window as i64;
        } else {
            self.hits.set(self.hits.get() + 1);
        }
        let off = slot * WINDOW * record + within * record;
        let score = f64::from_le_bytes(cache.data[off..off + 8].try_into().unwrap());
        let mut raw = [0u8; 8];
        raw[..M::BYTES].copy_from_slice(&cache.data[off + 8..off + 8 + M::BYTES]);
        let mask = M::from_u64(u64::from_le_bytes(raw));
        (score, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bnsl_spill_test_{tag}_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrips_all_records() {
        let n = 3 * WINDOW + 17; // exercise a partial tail window
        let bps: Vec<f64> = (0..n).map(|i| i as f64 * 0.5 - 100.0).collect();
        let bpm: Vec<u32> = (0..n).map(|i| (i * 7) as u32).collect();
        let lvl =
            SpilledLevel::write(&tmpdir("narrow"), 3, vec![0.0; 4], vec![0.0; 4], &bps, &bpm)
                .unwrap();
        for i in 0..n {
            let (s, m) = lvl.read(i);
            assert_eq!(s, bps[i], "record {i}");
            assert_eq!(m, bpm[i]);
        }
        assert_eq!(
            lvl.bytes_on_disk(),
            (HEADER + n * record_bytes::<u32>()) as u64
        );
    }

    #[test]
    fn roundtrips_wide_records_with_high_bits() {
        // u64 masks whose top half is populated — the narrow record
        // layout would truncate these.
        let n = WINDOW + 300;
        let bps: Vec<f64> = (0..n).map(|i| -(i as f64)).collect();
        let bpm: Vec<u64> = (0..n).map(|i| (i as u64) << 33 | i as u64).collect();
        let lvl =
            SpilledLevel::write(&tmpdir("wide"), 4, Vec::new(), Vec::new(), &bps, &bpm).unwrap();
        for i in (0..n).step_by(7) {
            let (s, m) = lvl.read(i);
            assert_eq!(s, bps[i]);
            assert_eq!(m, bpm[i], "high mask bits survive the roundtrip");
        }
        assert_eq!(
            lvl.bytes_on_disk(),
            (HEADER + n * record_bytes::<u64>()) as u64
        );
    }

    /// Satellite coverage: reads that straddle a window edge must hit the
    /// correct windows on both sides of the 4096-entry boundary, for both
    /// record widths.
    #[test]
    fn window_boundary_reads_are_exact() {
        fn check<M: VarMask>(tag: &str) {
            let n = 2 * WINDOW + 5;
            let bps: Vec<f64> = (0..n).map(|i| i as f64 + 0.25).collect();
            let bpm: Vec<M> = (0..n).map(|i| M::from_u64((i % 251) as u64)).collect();
            let lvl =
                SpilledLevel::write(&tmpdir(tag), 2, Vec::new(), Vec::new(), &bps, &bpm)
                    .unwrap();
            // straddle both boundaries: …, W−1, W, …, 2W−1, 2W, …
            for idx in [
                WINDOW - 2,
                WINDOW - 1,
                WINDOW,
                WINDOW + 1,
                2 * WINDOW - 1,
                2 * WINDOW,
                n - 1,
            ] {
                let (s, m) = lvl.read(idx);
                assert_eq!(s, bps[idx], "{tag}: score at {idx}");
                assert_eq!(m, bpm[idx], "{tag}: mask at {idx}");
            }
            let (_hits, misses) = lvl.cache_stats();
            assert!(misses >= 3, "{tag}: three distinct windows touched");
        }
        check::<u32>("boundary32");
        check::<u64>("boundary64");
    }

    #[test]
    fn header_records_version_and_width() {
        // Bulk-write a narrow and a wide level, then check the header
        // fields drive the reader's width validation.
        let dir = tmpdir("header");
        let lvl32 =
            SpilledLevel::<u32>::write(&dir, 1, Vec::new(), Vec::new(), &[1.0], &[7]).unwrap();
        assert_eq!(lvl32.bytes_on_disk(), (HEADER + 12) as u64);
        let lvl64 =
            SpilledLevel::<u64>::write(&dir, 1, Vec::new(), Vec::new(), &[1.0], &[7]).unwrap();
        assert_eq!(lvl64.bytes_on_disk(), (HEADER + 16) as u64);
    }

    #[test]
    fn random_access_pattern_is_correct_under_thrashing() {
        // more windows than slots → forced evictions
        let n = (SLOTS + 8) * WINDOW;
        let bps: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let bpm: Vec<u32> = (0..n).map(|i| i as u32).collect();
        let lvl =
            SpilledLevel::write(&tmpdir("thrash"), 5, Vec::new(), Vec::new(), &bps, &bpm)
                .unwrap();
        let mut state = 0x1234_5678_u64;
        for _ in 0..50_000 {
            state = crate::util::rng::splitmix64(&mut state);
            let i = (state % n as u64) as usize;
            let (s, m) = lvl.read(i);
            assert_eq!(m, i as u32);
            assert_eq!(s, bps[i]);
        }
        let (hits, misses) = lvl.cache_stats();
        assert!(misses > 0, "thrashing expected");
        assert_eq!(hits + misses, 50_000);
    }

    #[test]
    fn sequential_reads_mostly_hit() {
        let n = 4 * WINDOW;
        let bps = vec![1.5f64; n];
        let bpm = vec![9u32; n];
        let lvl =
            SpilledLevel::write(&tmpdir("seq"), 2, Vec::new(), Vec::new(), &bps, &bpm).unwrap();
        for i in 0..n {
            let _ = lvl.read(i);
        }
        let (hits, misses) = lvl.cache_stats();
        assert_eq!(misses, 4, "one miss per window");
        assert_eq!(hits, (n - 4) as u64);
    }

    #[test]
    fn resident_bytes_are_bounded_by_cache_not_level() {
        let n = SLOTS * 10 * WINDOW; // 640 windows on disk (~30 MiB)
        let lvl = SpilledLevel::<u32>::write(
            &tmpdir("resident"),
            7,
            vec![0.0; 10],
            vec![0.0; 10],
            &vec![0.0; n],
            &vec![0; n],
        )
        .unwrap();
        // resident = q/r + the fixed window cache, far below the level
        assert!(lvl.resident_bytes() < n * record_bytes::<u32>() / 8);
    }
}
