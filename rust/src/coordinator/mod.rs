//! L3 coordination utilities around the solvers.
//!
//! * [`spill`] — the paper's §5.3 extension: keep the level-`k`
//!   best-parent-set vectors on disk *at the peak levels only*, serving
//!   the level-`k+1` sweep through a windowed read cache. "The proposed
//!   method can reduce the memory peak by using the disk only at the peak
//!   or near-peak levels, rather than throughout the entire process."
//! * [`shard`] — the sharded frontier coordinator: every level split into
//!   `2^k` colex-rank shards with one spill writer per shard, a
//!   `manifest.json` committed per level, and disk-backed reconstruction —
//!   external-memory frontier search (Malone-style) plus cross-run
//!   `--resume`. Formats in `docs/FORMATS.md`.
//! * [`plan`] — the analytic level/memory planner behind Fig. 7 and the
//!   `bnsl exp levels` harness, including the sharded-run pricing.

pub mod plan;
pub mod shard;
pub mod spill;
