//! L3 coordination utilities around the solvers.
//!
//! * [`spill`] — the paper's §5.3 extension: keep the level-`k`
//!   best-parent-set vectors on disk *at the peak levels only*, serving
//!   the level-`k+1` sweep through a windowed read cache. "The proposed
//!   method can reduce the memory peak by using the disk only at the peak
//!   or near-peak levels, rather than throughout the entire process."
//! * [`shard`] — the sharded frontier coordinator: every level split into
//!   `2^k` colex-rank shards with one spill writer per shard, a
//!   `manifest.json` committed per level, and disk-backed reconstruction —
//!   external-memory frontier search (Malone-style) plus cross-run
//!   `--resume`. Formats in `docs/FORMATS.md`.
//! * [`cluster`] — the multi-host layer over [`shard`]: N independent
//!   processes cooperating through one shared directory via a per-level
//!   claim ledger (create-exclusive lock files, heartbeats, stale-claim
//!   reclaim) with a lowest-host-id committer election at every level
//!   barrier. Protocol in `docs/ARCHITECTURE.md`.
//! * [`storage`] — the pluggable durable-storage layer under [`shard`]
//!   and [`cluster`]: one [`storage::StorageBackend`] trait whose
//!   operations are the protocol steps, with a POSIX implementation
//!   (today's behavior, byte for byte) and an S3-semantics object-store
//!   implementation with injectable faults. Semantics table in
//!   `docs/ARCHITECTURE.md` §6.
//! * [`plan`] — the analytic level/memory planner behind Fig. 7 and the
//!   `bnsl exp levels` harness, including the sharded-run pricing,
//!   per-host handle budgets (POSIX) and request estimates (object).

pub mod cluster;
pub mod plan;
pub mod shard;
pub mod spill;
pub mod storage;
