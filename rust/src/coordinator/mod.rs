//! L3 coordination utilities around the solvers.
//!
//! * [`spill`] — the paper's §5.3 extension: keep the level-`k`
//!   best-parent-set vectors on disk *at the peak levels only*, serving
//!   the level-`k+1` sweep through a windowed read cache. "The proposed
//!   method can reduce the memory peak by using the disk only at the peak
//!   or near-peak levels, rather than throughout the entire process."
//! * [`plan`] — the analytic level/memory planner behind Fig. 7 and the
//!   `bnsl exp levels` harness.

pub mod plan;
pub mod spill;
