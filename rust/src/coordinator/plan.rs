//! Analytic memory/level planner — the model behind the paper's Fig. 7
//! and the §5.1 "maximum p on 16 GB" analysis, plus the pricing of
//! sharded runs ([`ShardedPlan`]) whose frontier lives entirely on disk.

use crate::bitset::BinomTable;
use crate::coordinator::shard::{
    fd_budget, reader_cache_bytes, PRN_BLOCK, PRN_RECORD, QR_RECORD, WINDOW,
};
use crate::coordinator::storage::object::PART_BYTES;
use crate::coordinator::storage::BackendKind;
use crate::util::json::Json;

/// Nominal what-if prune ratio used when pricing a `--prune` run before
/// any data has been seen (`bnsl info`). The *measured* ratio is
/// data-dependent — strong dependencies prune more, near-uniform noise
/// prunes nearly nothing — so this is a planning figure for the
/// "how much disk would pruning plausibly save" line, never a promise;
/// the bench harness records real ratios per dataset in `BENCH_ci.json`.
pub const NOMINAL_PRUNE_RATIO: f64 = 0.25;

/// Resource budgets a planned run is admitted against — the service
/// queue's admission contract ([`crate::service::queue`]) and the
/// `bnsl info` verdict source. Budgets describe what the *host* is
/// willing to spend, the plans describe what the run *needs*; the
/// [`BudgetVerdict`] is the comparison.
#[derive(Clone, Debug)]
pub struct Budgets {
    /// Peak resident RAM the run may plan for, in bytes.
    pub ram_bytes: u64,
    /// Open-file-descriptor ceiling (compare against
    /// [`ShardedPlan::fd_budget`]).
    pub fd_limit: u64,
    /// Object-store request ceiling per run; `None` = unmetered. Only
    /// consulted for object-backed plans.
    pub object_requests: Option<u64>,
}

impl Budgets {
    /// Budgets with no effective limits (every plan fits).
    pub fn unlimited() -> Budgets {
        Budgets {
            ram_bytes: u64::MAX,
            fd_limit: u64::MAX,
            object_requests: None,
        }
    }

    /// Detect this machine's budgets: total RAM from `/proc/meminfo`
    /// (falling back to 16 GiB off Linux) and the soft `RLIMIT_NOFILE`
    /// (falling back to 1024), requests unmetered.
    pub fn detect() -> Budgets {
        Budgets {
            ram_bytes: detect_ram_bytes().unwrap_or(16 << 30),
            fd_limit: crate::coordinator::shard::fd_soft_limit().unwrap_or(1024),
            object_requests: None,
        }
    }
}

/// `MemTotal` from `/proc/meminfo`, in bytes (`None` off Linux or if
/// unreadable).
fn detect_ram_bytes() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/meminfo").ok()?;
    let line = text.lines().find(|l| l.starts_with("MemTotal"))?;
    // "MemTotal:       16384256 kB"
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Whether a plan fits a set of [`Budgets`], and if not, why — each
/// reason names the figure, the budget it exceeds, and the knob to turn.
#[derive(Clone, Debug)]
pub struct BudgetVerdict {
    pub fits: bool,
    /// One sentence per exceeded budget; empty iff `fits`.
    pub reasons: Vec<String>,
}

impl BudgetVerdict {
    pub fn to_json(&self) -> Json {
        let mut reasons = Json::arr();
        for r in &self.reasons {
            reasons = reasons.push(r.as_str());
        }
        Json::obj().set("fits", self.fits).set("reasons", reasons)
    }
}

/// Per-level accounting of the proposed method's frontier.
#[derive(Clone, Debug)]
pub struct LevelPlan {
    pub k: usize,
    /// `C(p, k)` — the paper's Fig. 7 series
    pub combinations: u64,
    /// bytes of the level's frontier arrays: `C(p,k)·(16 + k·12)`
    /// (q + r f64 per subset, bps f64 + bpm u32 per member)
    pub frontier_bytes: u64,
    /// true while `k·C(p,k)` is within `threshold·max` — the near-peak
    /// region the §5.3 extension spills
    pub is_peak: bool,
}

/// Whole-run plan for `p` variables.
#[derive(Clone, Debug)]
pub struct MemoryPlan {
    pub p: usize,
    /// Bytes per stored parent mask: 4 while `p` fits the narrow `u32`
    /// path ([`crate::MAX_VARS`]), 8 beyond it (the wide `u64` path).
    /// Every byte figure below scales with this.
    pub mask_bytes: u64,
    pub levels: Vec<LevelPlan>,
    /// peak of two adjacent frontiers + the `(1+mask)·2^p` sink tables
    pub peak_bytes: u64,
    /// the level index at the peak (paper: 15 for p = 29)
    pub peak_level: usize,
    /// baseline (Silander all-in-RAM):
    /// `2^p·8 + p·2^p·(8+mask) + 2^p·(9+mask)`
    pub baseline_bytes: u64,
}

/// Build the plan (pure arithmetic; `p ≤ 62` supported analytically —
/// beyond the exact-DP caps, for feasibility studies). The record width
/// follows the width the solver would dispatch to: `u32` masks up to
/// [`crate::MAX_VARS`], `u64` masks above.
pub fn memory_plan(p: usize, spill_threshold: f64) -> MemoryPlan {
    assert!((1..=62).contains(&p), "analytic planner supports p ≤ 62");
    let mask_bytes: u64 = if p <= crate::MAX_VARS { 4 } else { 8 };
    let binom = BinomTable::new(p);
    let weights = binom.frontier_weights(p);
    let max_weight = *weights.iter().max().unwrap();
    let frontier =
        |k: usize| -> u64 { binom.c(p, k) * (16 + (8 + mask_bytes) * k as u64) };
    let levels: Vec<LevelPlan> = (0..=p)
        .map(|k| LevelPlan {
            k,
            combinations: binom.c(p, k),
            frontier_bytes: frontier(k),
            is_peak: spill_threshold > 0.0
                && weights[k] as f64 >= spill_threshold * max_weight as f64,
        })
        .collect();
    let sink_bytes = (1 + mask_bytes) << p;
    let (peak_level, peak_bytes) = (0..p)
        .map(|k| (k + 1, frontier(k) + frontier(k + 1) + sink_bytes))
        .max_by_key(|&(_, b)| b)
        .unwrap();
    let baseline_bytes = (8u64 << p)
        + (8 + mask_bytes) * (p as u64) * (1u64 << p)
        + ((9 + mask_bytes) << p);
    MemoryPlan {
        p,
        mask_bytes,
        levels,
        peak_bytes,
        peak_level,
        baseline_bytes,
    }
}

/// Analytic accounting of a sharded run ([`crate::coordinator::shard`]):
/// the frontier streams through per-shard files, so resident RAM is
/// worker buffers + window caches — per-shard frontier, not per-level —
/// and the former RAM peak (two frontiers + `2^p` sink tables) moves to
/// disk.
///
/// Cluster reading ([`crate::coordinator::cluster`], `--cluster`): every
/// figure here except `disk_bytes` is **per host** — each host runs its
/// own worker pool with `workers` threads, so `peak_resident_bytes` and
/// `fd_budget` price one machine, while the shard files and `.sink`
/// records land once on the shared mount.
#[derive(Clone, Debug)]
pub struct ShardedPlan {
    pub p: usize,
    pub shards: usize,
    /// Concurrent workers priced (defaults to one per shard).
    pub workers: usize,
    /// Subsets per engine batch per worker.
    pub batch: usize,
    pub mask_bytes: u64,
    /// Peak resident bytes across all levels: `workers ×`
    /// (batch write buffers + previous-level read caches).
    pub peak_resident_bytes: u64,
    /// The level at the resident peak.
    pub peak_level: usize,
    /// Disk high-water mark: two adjacent levels' `.bps`/`.qr` shard
    /// files (pre-prune) plus every committed level's `.sink` records
    /// (`(1+mask)·2^p` in total by the end — kept for reconstruction).
    pub disk_bytes: u64,
    /// Per-host open-file budget at the *planned* worker count: every
    /// worker's previous-level read handles + writer streams, plus
    /// process margin and the cluster claim-ledger headroom
    /// ([`crate::coordinator::shard::fd_budget`]), surfaced here so
    /// `bnsl info` reports it before a run dies at open time. This is a
    /// conservative ceiling on what the solvers preflight: `workers = 0`
    /// is priced as one worker per shard (actual runs additionally cap
    /// workers at the machine's core count, which the machine-agnostic
    /// planner cannot know), and single-host `solve_sharded` runs skip
    /// the ledger headroom. The solvers preflight this on *both*
    /// backends (the local object simulator still holds one real
    /// descriptor per open stream/reader); the object backend's own
    /// bill is additionally priced in requests
    /// ([`ShardedPlan::object_requests`]).
    pub fd_budget: u64,
    /// Estimated object-store request count of a full run on the
    /// `--backend object` path, where the bill is per request, not per
    /// file descriptor: staged uploads (one part PUT per
    /// [`PART_BYTES`] plus completion, copy and delete per stream),
    /// claim/done/finish control-document traffic, per-level manifest
    /// round-trips, and a **lower bound** of one ranged GET per window
    /// of the previous level's `.qr`/`.bps` streams (each worker reads
    /// its own range once when the cache is cold; re-fetches under
    /// cache pressure and heartbeat PUTs — which scale with wall time,
    /// not work — are excluded).
    pub object_requests: u64,
    /// The prune ratio this plan was priced at: the assumed fraction of
    /// level-`k` (`1 ≤ k < p`) subsets whose `.bps`/`.sink` records the
    /// bounds layer ([`crate::solver::bounds`]) skips. `0.0` prices the
    /// dense format exactly (no `.prn` sidecars); any positive ratio
    /// prices the slim prune format — per-record bytes scaled by
    /// `1 − ratio` plus the presence-sidecar overhead. `.qr` streams are
    /// never pruned (the next level's Eq. 9/10 pass reads every `q`).
    pub prune_ratio: f64,
}

/// Price a sharded run. `workers == 0` means one worker per shard;
/// `batch` is the per-worker engine batch ([`crate::solver::SolveOptions`]
/// default 1024). Pure arithmetic, `p ≤ 62` like [`memory_plan`].
pub fn sharded_plan(p: usize, shards: usize, workers: usize, batch: usize) -> ShardedPlan {
    sharded_plan_pruned(p, shards, workers, batch, 0.0)
}

/// [`sharded_plan`] at an assumed prune ratio. `prune_ratio = 0.0` is
/// *exactly* [`sharded_plan`] — the dense format, byte for byte (the
/// solver-accounting identity tests rely on this); a positive ratio
/// prices the slim prune format: `.bps`/`.sink` records scaled by
/// `1 − ratio` on the prunable levels (`1 ≤ k < p`; the full set is
/// never pruned), plus one `.prn` presence record ([`PRN_RECORD`] bytes
/// per [`PRN_BLOCK`] ranks, rounded up per shard) on every `k ≥ 1`
/// level. Write buffers are *not* scaled — the sweep still computes
/// every subset and fills full batches before the bound check drops
/// records at emission.
pub fn sharded_plan_pruned(
    p: usize,
    shards: usize,
    workers: usize,
    batch: usize,
    prune_ratio: f64,
) -> ShardedPlan {
    assert!((1..=62).contains(&p), "analytic planner supports p ≤ 62");
    assert!(shards >= 1 && shards.is_power_of_two());
    assert!(
        (0.0..=1.0).contains(&prune_ratio),
        "prune ratio is a fraction"
    );
    let workers = if workers == 0 { shards } else { workers.min(shards) };
    let batch = batch.max(1) as u64;
    let mask_bytes: u64 = if p <= crate::MAX_VARS { 4 } else { 8 };
    let binom = BinomTable::new(p);
    let bps_record = 8 + mask_bytes;
    let sink_record = 1 + mask_bytes;
    let pruned = prune_ratio > 0.0;
    // survivors after pruning `records` slim-format records at level k
    // (identity at ratio 0 and on the never-pruned levels 0 and p)
    let keep = |k: usize, records: u64| -> u64 {
        if !pruned || k == 0 || k == p {
            records
        } else {
            (records as f64 * (1.0 - prune_ratio)).ceil() as u64
        }
    };
    // `.prn` presence-sidecar bytes for one level (0 when the format is
    // dense): each shard rounds its span up to whole presence blocks
    let prn_level = |k: usize| -> u64 {
        if !pruned || k == 0 {
            return 0;
        }
        let width = binom.c(p, k).div_ceil(shards as u64).max(1);
        shards as u64 * width.div_ceil(PRN_BLOCK as u64) * PRN_RECORD as u64
    };
    // per-worker read caches over the previous level's shard files
    let read_cache = |k_prev: usize| -> u64 {
        let size = binom.c(p, k_prev);
        let per_shard = size.div_ceil(shards as u64).max(1);
        (0..shards)
            .map(|s| {
                let entries =
                    per_shard.min(size.saturating_sub(s as u64 * per_shard)) as usize;
                if entries == 0 {
                    return 0u64;
                }
                let qr = reader_cache_bytes(entries, QR_RECORD, shards) as u64;
                let bps = if k_prev == 0 {
                    0
                } else {
                    let rows = keep(k_prev, entries as u64 * k_prev as u64) as usize;
                    reader_cache_bytes(rows, bps_record as usize, shards) as u64
                };
                qr + bps
            })
            .sum()
    };
    let (peak_level, peak_resident_bytes) = (1..=p)
        .map(|k1| {
            let write_buffers =
                batch * (QR_RECORD as u64 + k1 as u64 * bps_record + sink_record);
            let per_worker = write_buffers + read_cache(k1 - 1);
            (k1, workers as u64 * per_worker)
        })
        .max_by_key(|&(_, b)| b)
        .unwrap();
    // disk: adjacent-level frontier files + cumulative sink records
    let frontier_files = |k: usize| -> u64 {
        binom.c(p, k) * QR_RECORD as u64
            + keep(k, binom.c(p, k) * k as u64) * bps_record
            + prn_level(k)
    };
    let mut sink_cum = 0u64;
    let mut disk_bytes = 0u64;
    for k1 in 1..=p {
        sink_cum += keep(k1, binom.c(p, k1)) * sink_record;
        disk_bytes = disk_bytes.max(frontier_files(k1 - 1) + frontier_files(k1) + sink_cum);
    }
    // object-backend request estimate (see the field docs): writes and
    // control traffic are exact by construction, window GETs are the
    // cold-cache lower bound
    let mut object_requests = 0u64;
    for k in 0..=p {
        let size = binom.c(p, k);
        let width = size.div_ceil(shards as u64).max(1);
        for s in 0..shards as u64 {
            let entries = width.min(size.saturating_sub(s * width));
            if entries == 0 {
                continue;
            }
            // the per-shard streams (three dense, four in prune
            // format): parts + completion + staged copy + staged
            // delete each
            let mut stream_bytes = vec![
                entries * QR_RECORD as u64,
                if k == 0 {
                    0
                } else {
                    keep(k, entries * k as u64) * bps_record
                },
                keep(k, entries) * sink_record,
            ];
            if pruned && k > 0 {
                stream_bytes
                    .push(entries.div_ceil(PRN_BLOCK as u64) * PRN_RECORD as u64);
            }
            for bytes in stream_bytes {
                object_requests += bytes.div_ceil(PART_BYTES).max(1) + 3;
            }
            // claim PUT + done-marker PUT + claim DELETE
            object_requests += 3;
        }
        // cold-cache ranged GETs while level k+1 reads level k
        if k < p {
            object_requests += size.div_ceil(WINDOW as u64);
            if k > 0 {
                object_requests += keep(k, size * k as u64).div_ceil(WINDOW as u64);
                if pruned {
                    // one GET per presence block the readers touch
                    object_requests += size.div_ceil(PRN_BLOCK as u64);
                }
            }
        }
        // barrier: finish-marker PUT + manifest GET/PUT round-trip
        object_requests += 4;
    }
    // reconstruction: one sink GET per level, plus one presence-block
    // GET each to map the optimal rank onto the slim stream
    object_requests += p as u64;
    if pruned {
        object_requests += p as u64;
    }
    ShardedPlan {
        p,
        shards,
        workers,
        batch: batch as usize,
        mask_bytes,
        peak_resident_bytes,
        peak_level,
        disk_bytes,
        fd_budget: fd_budget(workers, shards, true),
        object_requests,
        prune_ratio,
    }
}

impl ShardedPlan {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("p", self.p)
            .set("shards", self.shards)
            .set("workers", self.workers)
            .set("batch", self.batch)
            .set("mask_bytes", self.mask_bytes)
            .set("peak_resident_bytes", self.peak_resident_bytes)
            .set("peak_level", self.peak_level)
            .set("disk_bytes", self.disk_bytes)
            .set("fd_budget", self.fd_budget)
            .set("object_requests", self.object_requests)
            .set("prune_ratio", self.prune_ratio)
    }

    /// Does this plan fit `budgets` when run on `backend`? Admission for
    /// the service queue and the verdict `bnsl info` prints. The
    /// request-budget check applies to object-backed runs only (a POSIX
    /// run sends no object requests); RAM and fd ceilings apply to both
    /// (the shipped object backend is a local-fd-backed simulator, and a
    /// real one still holds sockets per stream).
    pub fn fits_budget(&self, backend: BackendKind, budgets: &Budgets) -> BudgetVerdict {
        let mut reasons = Vec::new();
        if self.peak_resident_bytes > budgets.ram_bytes {
            reasons.push(format!(
                "planned resident RAM {} exceeds the {} budget (lower \
                 --shards/--batch or raise the budget)",
                crate::util::human_bytes(self.peak_resident_bytes),
                crate::util::human_bytes(budgets.ram_bytes),
            ));
        }
        if self.fd_budget > budgets.fd_limit {
            reasons.push(format!(
                "planned open-file budget {} exceeds the {} descriptor \
                 limit (lower --shards, cap workers, or raise `ulimit -n`)",
                self.fd_budget, budgets.fd_limit,
            ));
        }
        if backend == BackendKind::Object {
            if let Some(cap) = budgets.object_requests {
                if self.object_requests > cap {
                    reasons.push(format!(
                        "estimated {} object-store requests exceed the {cap} \
                         request budget (lower --shards or raise the budget)",
                        self.object_requests,
                    ));
                }
            }
        }
        BudgetVerdict {
            fits: reasons.is_empty(),
            reasons,
        }
    }

    /// Stable-schema JSON record for one *backend-bound* plan: every key
    /// of [`ShardedPlan::to_json`] is always present, plus `backend` and
    /// the [`BudgetVerdict`] under `fits_budget`. `object_requests` is
    /// `null` (not omitted, not a misleading number) for POSIX-bound
    /// plans — a POSIX run sends no object requests, and downstream
    /// consumers (`bench_compare.py`-style) can rely on the key set
    /// being identical across backends.
    pub fn to_json_for(&self, backend: BackendKind, budgets: &Budgets) -> Json {
        let mut doc = self
            .to_json()
            .set("backend", backend.name())
            .set("fits_budget", self.fits_budget(backend, budgets).to_json());
        if backend == BackendKind::Posix {
            doc = doc.set("object_requests", Json::Null);
        }
        doc
    }
}

/// Bytes per sink record at level `k` of the **streaming** engine: 6
/// bits of sink position plus `k−1` bits of relative parent mask,
/// rounded up to whole bytes. Single source of truth shared with
/// [`crate::solver::StreamingSolver`]'s writer, so the pricing model
/// and the solver's actual allocations cannot drift.
pub fn streaming_record_bytes(k: usize) -> u64 {
    ((k + 5).div_ceil(8)) as u64
}

/// Analytic accounting of a memory-only streaming run
/// ([`crate::solver::StreamingSolver`], `--streaming`): the frontier is
/// identical to the resident path's ([`MemoryPlan`]), but the
/// `(1+mask)·2^p` sink tables are replaced by per-level compact record
/// streams — `C(p,k)·⌈(k+5)/8⌉` bytes at level `k`, retained through
/// reconstruction — so the working set at level `k` only carries the
/// streams accumulated *so far*, not the full-lattice tables.
#[derive(Clone, Debug)]
pub struct StreamingPlan {
    pub p: usize,
    /// Bytes per stored parent mask (4 narrow, 8 wide), like
    /// [`MemoryPlan::mask_bytes`].
    pub mask_bytes: u64,
    /// Peak resident bytes: max over levels of two adjacent frontiers
    /// plus the record streams accumulated through that level. Equals
    /// the solver's own `peak_state_bytes` accounting exactly
    /// (test-asserted in `solver/streaming.rs`).
    pub peak_bytes: u64,
    /// The level index at the peak.
    pub peak_level: usize,
    /// Total retained record-stream bytes, `Σ_k C(p,k)·⌈(k+5)/8⌉` —
    /// what reconstruction reads at the end.
    pub record_stream_bytes: u64,
    /// The resident path's `(1+mask)·2^p` sink tables for the same
    /// width — the figure the streams replace (strictly larger for all
    /// exact-DP-range `p`; test-asserted at `p ≥ 20`).
    pub resident_sink_bytes: u64,
    /// The prune ratio this plan was priced at (`0.0` = the dense
    /// streams, exactly [`streaming_plan`]; positive = in-sweep flag
    /// vectors plus post-sweep compaction to `1 − ratio` of each
    /// prunable level's records, retained with a rank→slot presence
    /// map). See [`streaming_plan_pruned`].
    pub prune_ratio: f64,
}

/// Price a streaming run. Pure arithmetic, `p ≤ 62` like
/// [`memory_plan`]; record width follows the dispatch width (`u32`
/// masks up to [`crate::MAX_VARS`], `u64` above).
pub fn streaming_plan(p: usize) -> StreamingPlan {
    let mask_bytes: u64 = if p <= crate::MAX_VARS { 4 } else { 8 };
    streaming_plan_pruned_for_mask_bytes(p, mask_bytes, 0.0)
}

/// [`streaming_plan`] with an explicit mask width — for pricing a
/// forced-wide run (`StreamingSolver::<u64>` on a narrow-range `p`).
pub fn streaming_plan_for_mask_bytes(p: usize, mask_bytes: u64) -> StreamingPlan {
    streaming_plan_pruned_for_mask_bytes(p, mask_bytes, 0.0)
}

/// [`streaming_plan`] at an assumed prune ratio. `prune_ratio = 0.0` is
/// *exactly* [`streaming_plan`] — the solver's own `peak_state_bytes`
/// accounting is test-asserted against it. A positive ratio models the
/// prune-format sweep: each prunable level (`1 ≤ k < p`) carries a
/// one-byte-per-subset flag vector *during* its sweep (the records are
/// written densely first — pruning drops emissions, not computation),
/// then compacts to `1 − ratio` of its records plus a rank→slot
/// presence map (one bit per rank + one `u64` survivor prefix per
/// [`PRN_BLOCK`] ranks) retained through reconstruction.
pub fn streaming_plan_pruned(p: usize, prune_ratio: f64) -> StreamingPlan {
    let mask_bytes: u64 = if p <= crate::MAX_VARS { 4 } else { 8 };
    streaming_plan_pruned_for_mask_bytes(p, mask_bytes, prune_ratio)
}

/// [`streaming_plan_pruned`] with an explicit mask width.
pub fn streaming_plan_pruned_for_mask_bytes(
    p: usize,
    mask_bytes: u64,
    prune_ratio: f64,
) -> StreamingPlan {
    assert!((1..=62).contains(&p), "analytic planner supports p ≤ 62");
    assert!(
        (0.0..=1.0).contains(&prune_ratio),
        "prune ratio is a fraction"
    );
    let pruned = prune_ratio > 0.0;
    let binom = BinomTable::new(p);
    let frontier =
        |k: usize| -> u64 { binom.c(p, k) * (16 + (8 + mask_bytes) * k as u64) };
    let mut stream_cum = 0u64;
    let mut peak_bytes = 0u64;
    let mut peak_level = 0usize;
    for k1 in 1..=p {
        let size = binom.c(p, k1);
        let rec = streaming_record_bytes(k1);
        // in-sweep high-water: the level's stream is dense (plus its
        // flag vector) until the post-sweep compaction
        let in_sweep = frontier(k1 - 1)
            + frontier(k1)
            + stream_cum
            + size * rec
            + if pruned { size } else { 0 };
        let kept = if pruned && k1 < p {
            (size as f64 * (1.0 - prune_ratio)).ceil() as u64
        } else {
            size
        };
        let map = if pruned {
            size.div_ceil(8) + size.div_ceil(PRN_BLOCK as u64) * 8
        } else {
            0
        };
        stream_cum += kept * rec + map;
        let bytes = in_sweep.max(frontier(k1 - 1) + frontier(k1) + stream_cum);
        if bytes > peak_bytes {
            peak_bytes = bytes;
            peak_level = k1;
        }
    }
    StreamingPlan {
        p,
        mask_bytes,
        peak_bytes,
        peak_level,
        record_stream_bytes: stream_cum,
        resident_sink_bytes: (1 + mask_bytes) << p,
        prune_ratio,
    }
}

impl StreamingPlan {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("p", self.p)
            .set("mask_bytes", self.mask_bytes)
            .set("peak_bytes", self.peak_bytes)
            .set("peak_level", self.peak_level)
            .set("record_stream_bytes", self.record_stream_bytes)
            .set("resident_sink_bytes", self.resident_sink_bytes)
            .set("prune_ratio", self.prune_ratio)
    }

    /// Does this plan fit `budgets`? Streaming is memory-only: the only
    /// ceiling that can bind is resident RAM — it opens no per-shard
    /// files and sends no object requests, so those budgets are
    /// irrelevant by construction.
    pub fn fits_budget(&self, budgets: &Budgets) -> BudgetVerdict {
        let mut reasons = Vec::new();
        if self.peak_bytes > budgets.ram_bytes {
            reasons.push(format!(
                "planned resident RAM {} exceeds the {} budget (the \
                 streaming engine is memory-only — use --spill-dir or \
                 --shards past it, or raise the budget)",
                crate::util::human_bytes(self.peak_bytes),
                crate::util::human_bytes(budgets.ram_bytes),
            ));
        }
        BudgetVerdict {
            fits: reasons.is_empty(),
            reasons,
        }
    }

    /// Stable-schema JSON record: every key of
    /// [`StreamingPlan::to_json`] plus the [`BudgetVerdict`] under
    /// `fits_budget` — the record `bnsl info --json` ships under
    /// `streaming_plans`.
    pub fn to_json_for(&self, budgets: &Budgets) -> Json {
        self.to_json()
            .set("fits_budget", self.fits_budget(budgets).to_json())
    }
}

/// Analytic accounting of a search-tier job ([`crate::search`], the
/// service's `mode: fast | anytime`). The approximate ordering/hill-climb
/// pass touches no subset lattice at all — its working set is the
/// dataset plus bounded per-variable scorer state — so a `fast` job is
/// priced as effectively free next to any exact plan. An `anytime` job
/// runs the same approximate pass and then the *resident* exact sweep
/// in-process, so its peak is the [`MemoryPlan`] peak on top of the
/// search pass.
#[derive(Clone, Debug)]
pub struct SearchPlan {
    pub p: usize,
    /// Dataset rows the search scores.
    pub n: usize,
    /// `true` = anytime (search, then the resident exact sweep);
    /// `false` = fast (search only, no sweep ever starts).
    pub exact: bool,
    /// Resident bytes of the approximate pass alone: two copies of the
    /// `n·p` value matrix (raw + column-major scorer view) plus a loose
    /// `p²` ceiling on live family masks/scores during a sweep.
    pub search_bytes: u64,
    /// The resident exact sweep's planned peak ([`memory_plan`]); 0 for
    /// fast plans.
    pub exact_peak_bytes: u64,
    /// `search_bytes + exact_peak_bytes` — the figure admission prices.
    pub peak_bytes: u64,
}

/// Price a search-tier run. Pure arithmetic like [`memory_plan`];
/// `exact = true` additionally prices the resident sweep, so it is
/// restricted to the analytic planner's `p ≤ 62` range (the service
/// validates `anytime` against the much lower exact-DP caps anyway),
/// while `fast` plans go up to [`crate::MAX_NET_VARS`].
pub fn search_plan(p: usize, n: usize, exact: bool) -> SearchPlan {
    assert!(
        (1..=crate::MAX_NET_VARS).contains(&p),
        "search tier supports p ≤ MAX_NET_VARS"
    );
    let search_bytes =
        2 * (n as u64) * (p as u64) + (p as u64) * (p as u64) * 16 + (64 << 10);
    let exact_peak_bytes = if exact {
        memory_plan(p, 0.0).peak_bytes
    } else {
        0
    };
    SearchPlan {
        p,
        n,
        exact,
        search_bytes,
        exact_peak_bytes,
        peak_bytes: search_bytes + exact_peak_bytes,
    }
}

impl SearchPlan {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("p", self.p)
            .set("n", self.n)
            .set("mode", if self.exact { "anytime" } else { "fast" })
            .set("search_bytes", self.search_bytes)
            .set("exact_peak_bytes", self.exact_peak_bytes)
            .set("peak_bytes", self.peak_bytes)
    }

    /// Does this plan fit `budgets`? The search tier is memory-only and
    /// in-process: like [`StreamingPlan::fits_budget`], the only ceiling
    /// that can bind is resident RAM — no shard files, no object
    /// requests.
    pub fn fits_budget(&self, budgets: &Budgets) -> BudgetVerdict {
        let mut reasons = Vec::new();
        if self.peak_bytes > budgets.ram_bytes {
            reasons.push(format!(
                "planned resident RAM {} exceeds the {} budget (an anytime \
                 job carries the resident exact sweep — submit mode:fast \
                 or an exact sharded run instead, or raise the budget)",
                crate::util::human_bytes(self.peak_bytes),
                crate::util::human_bytes(budgets.ram_bytes),
            ));
        }
        BudgetVerdict {
            fits: reasons.is_empty(),
            reasons,
        }
    }

    /// Stable-schema JSON record: every key of [`SearchPlan::to_json`]
    /// plus the [`BudgetVerdict`] under `fits_budget`.
    pub fn to_json_for(&self, budgets: &Budgets) -> Json {
        self.to_json()
            .set("fits_budget", self.fits_budget(budgets).to_json())
    }
}

impl MemoryPlan {
    /// Largest `p` whose planned peak fits a byte budget (paper §5.1:
    /// 16 GB ⇒ 26 for the baseline vs 28 for the proposed method). The
    /// scan crosses the u32→u64 record-width boundary at
    /// `p = MAX_VARS + 1`, so wide-path feasibility is priced honestly.
    pub fn max_p_within(budget_bytes: u64, baseline: bool) -> usize {
        let mut best = 0;
        for p in 1..=40 {
            let plan = memory_plan(p, 0.0);
            let need = if baseline {
                plan.baseline_bytes
            } else {
                plan.peak_bytes
            };
            if need <= budget_bytes {
                best = p;
            }
        }
        best
    }

    pub fn to_json(&self) -> Json {
        let mut levels = Json::arr();
        for l in &self.levels {
            levels = levels.push(
                Json::obj()
                    .set("k", l.k)
                    .set("combinations", l.combinations)
                    .set("frontier_bytes", l.frontier_bytes)
                    .set("is_peak", l.is_peak),
            );
        }
        Json::obj()
            .set("p", self.p)
            .set("mask_bytes", self.mask_bytes)
            .set("peak_bytes", self.peak_bytes)
            .set("peak_level", self.peak_level)
            .set("baseline_bytes", self.baseline_bytes)
            .set("levels", levels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_peak_level_for_p29_is_15() {
        // Paper §5.3: "Considering 29 variables … the 15th level will be
        // the peak of memory usage."
        let plan = memory_plan(29, 0.5);
        assert_eq!(plan.peak_level, 15);
    }

    #[test]
    fn fig7_combination_series_is_symmetric_and_peaks_mid() {
        let plan = memory_plan(29, 0.0);
        let combos: Vec<u64> = plan.levels.iter().map(|l| l.combinations).collect();
        assert_eq!(combos[0], 1);
        assert_eq!(combos[29], 1);
        for k in 0..=29 {
            assert_eq!(combos[k], combos[29 - k]);
        }
        let argmax = combos
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap()
            .0;
        assert!(argmax == 14 || argmax == 15);
    }

    #[test]
    fn paper_81gb_estimate_for_p29_level15_reproduced() {
        // §5.3: at p = 29 the level-15 parent-set vector alone is
        // C(28,14)·29·8 bytes = 8.6679 GB (the paper's accounting). Our
        // frontier counts k·C(p,k)·8 for bps, which equals the same
        // quantity: 15·C(29,15)·8 … check the paper's own figure via its
        // formula:
        let binom = BinomTable::new(29);
        let paper_bytes = binom.c(28, 14) * 29 * 8;
        let gb = paper_bytes as f64 / (1024.0 * 1024.0 * 1024.0);
        assert!((gb - 8.6679).abs() < 0.01, "{gb}");
    }

    #[test]
    fn proposed_beats_baseline_memory_for_all_p() {
        for p in 4..=30 {
            let plan = memory_plan(p, 0.0);
            assert!(
                plan.peak_bytes < plan.baseline_bytes,
                "p={p}: {} vs {}",
                plan.peak_bytes,
                plan.baseline_bytes
            );
        }
    }

    #[test]
    fn max_p_within_16gb_matches_paper_claims() {
        let budget = 16u64 << 30;
        let baseline = MemoryPlan::max_p_within(budget, true);
        let proposed = MemoryPlan::max_p_within(budget, false);
        // §5.1: "the upper limit is 26 variables, whereas our proposed
        // method can handle up to 28." Our accounting includes the
        // reconstruction tables the paper ignores, so allow ±1.
        assert!(
            (25..=27).contains(&baseline),
            "baseline max p = {baseline}"
        );
        assert!(
            (27..=29).contains(&proposed),
            "proposed max p = {proposed}"
        );
        assert!(proposed >= baseline + 2);
    }

    #[test]
    fn wide_plans_use_eight_byte_masks() {
        let narrow = memory_plan(30, 0.0);
        assert_eq!(narrow.mask_bytes, 4);
        let wide = memory_plan(31, 0.0);
        assert_eq!(wide.mask_bytes, 8);
        // frontier records are 16 bytes/member on the wide path
        let k = 10;
        assert_eq!(
            wide.levels[k].frontier_bytes,
            wide.levels[k].combinations * (16 + 16 * k as u64)
        );
        // p=33 (the spill-assisted target): plan is finite and the sink
        // tables price in 9-byte entries
        let p33 = memory_plan(33, 0.5);
        assert!(p33.levels.iter().any(|l| l.is_peak));
        assert!(p33.peak_bytes > (9u64 << 33));
    }

    /// Acceptance criterion (ISSUE 2): at p = 20 a 4-shard run's planned
    /// peak RAM is strictly below the unsharded two-level frontier.
    #[test]
    fn p20_four_shards_resident_strictly_below_unsharded() {
        let unsharded = memory_plan(20, 0.0);
        let sharded = sharded_plan(20, 4, 0, 1024);
        assert!(
            sharded.peak_resident_bytes < unsharded.peak_bytes,
            "sharded {} vs unsharded {}",
            sharded.peak_resident_bytes,
            unsharded.peak_bytes
        );
    }

    #[test]
    fn sharded_resident_is_flat_where_unsharded_explodes() {
        // p = 33 is deep in wide-path territory: the unsharded peak is
        // hundreds of GB, the sharded resident stays in cache territory
        // because the frontier and sink tables live on disk.
        let unsharded = memory_plan(33, 0.0);
        let sharded = sharded_plan(33, 8, 0, 1024);
        assert!(unsharded.peak_bytes > 100u64 << 30);
        assert!(sharded.peak_resident_bytes < 1u64 << 30);
        // ...and the bill moved to disk, it did not vanish
        assert!(sharded.disk_bytes > 10u64 << 30);
        assert_eq!(sharded.mask_bytes, 8);
    }

    #[test]
    fn sharded_plan_prices_the_cap_and_respects_worker_clamp() {
        // the sharded cap (MAX_VARS_SHARDED) is disk-bound: single-digit
        // TB of shard files at the cap, still finite and plan-able
        let cap = sharded_plan(crate::MAX_VARS_SHARDED, 16, 0, 1024);
        assert!(cap.disk_bytes > 1u64 << 40, "TB-scale disk at the cap");
        assert!(cap.peak_resident_bytes < 4u64 << 30, "RAM stays commodity");
        // workers default to one per shard and never exceed the shards
        assert_eq!(sharded_plan(20, 4, 0, 64).workers, 4);
        assert_eq!(sharded_plan(20, 4, 9, 64).workers, 4);
        assert_eq!(sharded_plan(20, 4, 2, 64).workers, 2);
        let j = cap.to_json().to_string();
        assert!(j.contains("peak_resident_bytes"), "{j}");
        assert!(j.contains("fd_budget"), "{j}");
    }

    /// Satellite (ISSUE 3): the per-host handle budget is part of the
    /// plan. With an explicit worker count it equals the cluster
    /// preflight figure; with `workers = 0` it is the machine-agnostic
    /// one-per-shard ceiling (runs additionally clamp to core count).
    #[test]
    fn sharded_plan_surfaces_the_per_host_fd_budget() {
        let plan = sharded_plan(20, 8, 3, 1024);
        assert_eq!(plan.workers, 3);
        assert_eq!(plan.fd_budget, fd_budget(3, 8, true));
        // budget grows with both knobs the error message names
        assert!(sharded_plan(20, 16, 3, 1024).fd_budget > plan.fd_budget);
        assert!(sharded_plan(20, 8, 8, 1024).fd_budget > plan.fd_budget);
    }

    /// Satellite (ISSUE 4): the object backend is priced in requests.
    /// The estimate is dominated by control traffic and window GETs at
    /// small p, must grow with both p and the shard count, and lands in
    /// the JSON record `bnsl info` prints.
    #[test]
    fn sharded_plan_prices_object_requests() {
        let small = sharded_plan(12, 4, 0, 1024);
        // every non-empty shard costs at least its three stream uploads
        // (4 requests each) plus 3 control documents
        assert!(
            small.object_requests > 12 * 4 * 3,
            "{}",
            small.object_requests
        );
        // more levels → more requests
        assert!(sharded_plan(20, 4, 0, 1024).object_requests > small.object_requests);
        // more shards → more per-shard uploads and control documents
        assert!(
            sharded_plan(12, 16, 0, 1024).object_requests > small.object_requests,
            "request bill grows with the shard count"
        );
        // the estimate stays finite and JSON-serialisable at the cap
        let cap = sharded_plan(crate::MAX_VARS_SHARDED, 64, 0, 1024);
        assert!(cap.object_requests > 0);
        let j = cap.to_json().to_string();
        assert!(j.contains("object_requests"), "{j}");
    }

    /// Satellite (ISSUE 5): plans carry a budget verdict the service
    /// queue admits against, and the backend-bound JSON schema is
    /// stable — `object_requests` is null (present!) on posix plans.
    #[test]
    fn fits_budget_names_each_exceeded_ceiling() {
        let plan = sharded_plan(20, 8, 2, 1024);
        let roomy = Budgets::unlimited();
        let v = plan.fits_budget(BackendKind::Posix, &roomy);
        assert!(v.fits && v.reasons.is_empty());
        // RAM ceiling below the plan's resident peak
        let tight_ram = Budgets {
            ram_bytes: plan.peak_resident_bytes - 1,
            ..Budgets::unlimited()
        };
        let v = plan.fits_budget(BackendKind::Posix, &tight_ram);
        assert!(!v.fits);
        assert!(v.reasons.iter().any(|r| r.contains("resident RAM")), "{v:?}");
        // fd ceiling below the plan's handle budget
        let tight_fd = Budgets {
            fd_limit: plan.fd_budget - 1,
            ..Budgets::unlimited()
        };
        let v = plan.fits_budget(BackendKind::Posix, &tight_fd);
        assert!(!v.fits);
        assert!(v.reasons.iter().any(|r| r.contains("open-file")), "{v:?}");
        // the request budget binds object-backed plans only
        let tight_req = Budgets {
            object_requests: Some(1),
            ..Budgets::unlimited()
        };
        assert!(plan.fits_budget(BackendKind::Posix, &tight_req).fits);
        let v = plan.fits_budget(BackendKind::Object, &tight_req);
        assert!(!v.fits);
        assert!(v.reasons.iter().any(|r| r.contains("request")), "{v:?}");
        // two ceilings exceeded -> two reasons
        let both = Budgets {
            ram_bytes: 1,
            fd_limit: 1,
            object_requests: None,
        };
        assert_eq!(plan.fits_budget(BackendKind::Posix, &both).reasons.len(), 2);
    }

    #[test]
    fn backend_bound_plan_json_schema_is_stable() {
        let plan = sharded_plan(16, 4, 0, 1024);
        let budgets = Budgets::unlimited();
        let posix = plan.to_json_for(BackendKind::Posix, &budgets);
        let object = plan.to_json_for(BackendKind::Object, &budgets);
        // identical key sets — consumers never branch on presence
        let keys = |j: &Json| -> Vec<String> {
            match j {
                Json::Obj(fields) => fields.iter().map(|(k, _)| k.clone()).collect(),
                _ => panic!("plan record must be an object"),
            }
        };
        assert_eq!(keys(&posix), keys(&object));
        // posix: object_requests present but null; object: a number
        assert_eq!(posix.get("object_requests"), Some(&Json::Null));
        assert!(object.get("object_requests").and_then(Json::as_u64).unwrap() > 0);
        assert_eq!(posix.get("backend").and_then(Json::as_str), Some("posix"));
        // the verdict rides along with a fits flag and a reasons array
        let verdict = posix.get("fits_budget").expect("fits_budget present");
        assert_eq!(verdict.get("fits"), Some(&Json::Bool(true)));
        assert!(verdict.get("reasons").and_then(Json::as_arr).is_some());
    }

    /// Acceptance criterion (ISSUE 6): the streaming model's retained
    /// reconstruction state — and its whole resident peak — sit strictly
    /// below the resident path's `2^p` sink-table footprint at `p ≥ 20`,
    /// on both mask widths.
    #[test]
    fn streaming_records_strictly_undercut_resident_sink_tables() {
        for p in 20..=30 {
            let s = streaming_plan(p);
            let resident = memory_plan(p, 0.0);
            assert_eq!(s.mask_bytes, resident.mask_bytes);
            assert!(
                s.record_stream_bytes < s.resident_sink_bytes,
                "p={p}: streams {} vs sink tables {}",
                s.record_stream_bytes,
                s.resident_sink_bytes
            );
            assert!(
                s.peak_bytes < resident.peak_bytes,
                "p={p}: streaming peak {} vs resident peak {}",
                s.peak_bytes,
                resident.peak_bytes
            );
        }
        // wide records (9-byte sink entries) are undercut even harder
        for p in 20..=crate::MAX_VARS_STREAMING {
            let s = streaming_plan_for_mask_bytes(p, 8);
            assert!(s.record_stream_bytes < (9u64 << p), "p={p}");
        }
    }

    #[test]
    fn streaming_record_width_grows_with_the_level() {
        // 6 bits of position + k−1 relative bits, whole bytes
        assert_eq!(streaming_record_bytes(1), 1);
        assert_eq!(streaming_record_bytes(3), 1);
        assert_eq!(streaming_record_bytes(4), 2);
        assert_eq!(streaming_record_bytes(11), 2);
        assert_eq!(streaming_record_bytes(12), 3);
        assert_eq!(streaming_record_bytes(27), 4);
        // the widest level any streaming run can reach still fits a u64
        assert_eq!(streaming_record_bytes(crate::MAX_VARS_STREAMING), 5);
    }

    /// Satellite (ISSUE 6): streaming admission is RAM-only — fd and
    /// object-request ceilings never bind a plan that opens no files.
    #[test]
    fn streaming_fits_budget_prices_ram_only() {
        let plan = streaming_plan(20);
        assert!(plan.fits_budget(&Budgets::unlimited()).fits);
        let tight_ram = Budgets {
            ram_bytes: plan.peak_bytes - 1,
            ..Budgets::unlimited()
        };
        let v = plan.fits_budget(&tight_ram);
        assert!(!v.fits);
        assert!(v.reasons.iter().any(|r| r.contains("resident RAM")), "{v:?}");
        let tight_everything_else = Budgets {
            ram_bytes: u64::MAX,
            fd_limit: 0,
            object_requests: Some(0),
        };
        assert!(plan.fits_budget(&tight_everything_else).fits);
    }

    /// Satellite (ISSUE 6): the `bnsl info --json` streaming record has
    /// a stable key set with the verdict attached.
    #[test]
    fn streaming_plan_json_schema_is_stable() {
        let doc = streaming_plan(16).to_json_for(&Budgets::unlimited());
        let keys = |j: &Json| -> Vec<String> {
            match j {
                Json::Obj(fields) => fields.iter().map(|(k, _)| k.clone()).collect(),
                _ => panic!("plan record must be an object"),
            }
        };
        assert_eq!(
            keys(&doc),
            vec![
                "p",
                "mask_bytes",
                "peak_bytes",
                "peak_level",
                "record_stream_bytes",
                "resident_sink_bytes",
                "prune_ratio",
                "fits_budget",
            ]
        );
        let verdict = doc.get("fits_budget").expect("fits_budget present");
        assert_eq!(verdict.get("fits"), Some(&Json::Bool(true)));
        assert!(verdict.get("reasons").and_then(Json::as_arr).is_some());
    }

    /// Tentpole (ISSUE 8): ratio-0 pruned plans ARE the dense plans —
    /// no hidden sidecar overhead — and a positive ratio moves the disk
    /// and request bills down while `.qr` (dense by design) holds them
    /// above a floor.
    #[test]
    fn pruned_plans_delegate_at_ratio_zero_and_shrink_disk() {
        let dense = sharded_plan(20, 4, 0, 1024);
        let zero = sharded_plan_pruned(20, 4, 0, 1024, 0.0);
        assert_eq!(zero.disk_bytes, dense.disk_bytes);
        assert_eq!(zero.peak_resident_bytes, dense.peak_resident_bytes);
        assert_eq!(zero.object_requests, dense.object_requests);
        assert_eq!(zero.prune_ratio, 0.0);
        let half = sharded_plan_pruned(20, 4, 0, 1024, 0.5);
        assert!(half.disk_bytes < dense.disk_bytes, "bps/sink bytes shrink");
        assert!(
            half.object_requests < dense.object_requests,
            "fewer upload parts and window GETs"
        );
        // monotone in the ratio, with the dense .qr streams as a floor
        let deep = sharded_plan_pruned(20, 4, 0, 1024, 0.9);
        assert!(deep.disk_bytes < half.disk_bytes);
        let binom = BinomTable::new(20);
        let qr_floor = (0..20u64)
            .map(|k| {
                binom.c(20, k as usize) * QR_RECORD as u64
                    + binom.c(20, k as usize + 1) * QR_RECORD as u64
            })
            .max()
            .unwrap();
        assert!(deep.disk_bytes > qr_floor, "q stays dense at every ratio");
        let j = half.to_json().to_string();
        assert!(j.contains("\"prune_ratio\":0.5"), "{j}");
    }

    /// Tentpole (ISSUE 8): streaming pruned pricing. Ratio 0 is the
    /// dense model exactly (the solver's accounting identity test rides
    /// on it); a positive ratio shrinks the *retained* streams but the
    /// in-sweep high-water still carries the dense level plus its flag
    /// vector, so the peak never undercuts honest bookkeeping.
    #[test]
    fn streaming_pruned_pricing_shrinks_retained_streams_only() {
        let dense = streaming_plan(22);
        let zero = streaming_plan_pruned(22, 0.0);
        assert_eq!(zero.peak_bytes, dense.peak_bytes);
        assert_eq!(zero.peak_level, dense.peak_level);
        assert_eq!(zero.record_stream_bytes, dense.record_stream_bytes);
        let half = streaming_plan_pruned(22, 0.5);
        assert!(
            half.record_stream_bytes < dense.record_stream_bytes,
            "retained streams compact to survivors + presence maps"
        );
        // the peak includes the dense in-sweep stream + flags, so it is
        // never below the level frontiers alone and can exceed the
        // dense model's peak only by the flag vector
        assert!(half.peak_bytes >= dense.peak_bytes - dense.record_stream_bytes);
        let nominal = streaming_plan_pruned(22, NOMINAL_PRUNE_RATIO);
        assert_eq!(nominal.prune_ratio, NOMINAL_PRUNE_RATIO);
    }

    /// Tentpole (ISSUE 9): the anytime admission prices the approximate
    /// pass as ~free — a fast plan's peak is dataset-scale, orders of
    /// magnitude under any exact plan — while an anytime plan carries
    /// the full resident exact peak on top.
    #[test]
    fn search_plan_prices_fast_as_nearly_free_and_anytime_as_resident() {
        let fast = search_plan(20, 1000, false);
        assert_eq!(fast.exact_peak_bytes, 0);
        assert_eq!(fast.peak_bytes, fast.search_bytes);
        let resident = memory_plan(20, 0.0);
        assert!(
            fast.peak_bytes * 100 < resident.peak_bytes,
            "fast {} vs resident {}",
            fast.peak_bytes,
            resident.peak_bytes
        );
        let anytime = search_plan(20, 1000, true);
        assert_eq!(anytime.exact_peak_bytes, resident.peak_bytes);
        assert_eq!(
            anytime.peak_bytes,
            anytime.search_bytes + resident.peak_bytes
        );
        // fast goes beyond the exact caps (the search-only regime)
        let big = search_plan(crate::MAX_NET_VARS, 5000, false);
        assert!(big.peak_bytes < 1 << 24, "still tiny at p = 64");
    }

    /// Tentpole (ISSUE 9): search admission is RAM-only, and the JSON
    /// record has a stable key set with the verdict attached.
    #[test]
    fn search_plan_budget_and_json_schema() {
        let plan = search_plan(18, 500, true);
        assert!(plan.fits_budget(&Budgets::unlimited()).fits);
        let tight = Budgets {
            ram_bytes: plan.peak_bytes - 1,
            ..Budgets::unlimited()
        };
        let v = plan.fits_budget(&tight);
        assert!(!v.fits);
        assert!(v.reasons.iter().any(|r| r.contains("resident RAM")), "{v:?}");
        // fd/request ceilings never bind
        let odd = Budgets {
            ram_bytes: u64::MAX,
            fd_limit: 0,
            object_requests: Some(0),
        };
        assert!(plan.fits_budget(&odd).fits);
        let doc = plan.to_json_for(&Budgets::unlimited());
        let keys = |j: &Json| -> Vec<String> {
            match j {
                Json::Obj(fields) => fields.iter().map(|(k, _)| k.clone()).collect(),
                _ => panic!("plan record must be an object"),
            }
        };
        assert_eq!(
            keys(&doc),
            vec![
                "p",
                "n",
                "mode",
                "search_bytes",
                "exact_peak_bytes",
                "peak_bytes",
                "fits_budget",
            ]
        );
        assert_eq!(doc.get("mode").and_then(Json::as_str), Some("anytime"));
        assert_eq!(
            search_plan(18, 500, false)
                .to_json()
                .get("mode")
                .and_then(Json::as_str),
            Some("fast")
        );
    }

    #[test]
    fn detected_budgets_are_sane() {
        let b = Budgets::detect();
        assert!(b.ram_bytes >= 1 << 20, "at least a megabyte of RAM");
        assert!(b.fd_limit >= 16, "some descriptors available");
        assert!(b.object_requests.is_none(), "requests unmetered by default");
    }

    #[test]
    fn spill_threshold_marks_near_peak_levels_only() {
        let plan = memory_plan(20, 0.9);
        let peaks: Vec<usize> = plan
            .levels
            .iter()
            .filter(|l| l.is_peak)
            .map(|l| l.k)
            .collect();
        assert!(!peaks.is_empty());
        assert!(peaks.len() < 8, "only near-peak levels spill: {peaks:?}");
        assert!(peaks.contains(&11) || peaks.contains(&10));
    }
}
