//! Analytic memory/level planner — the model behind the paper's Fig. 7
//! and the §5.1 "maximum p on 16 GB" analysis.

use crate::bitset::BinomTable;
use crate::util::json::Json;

/// Per-level accounting of the proposed method's frontier.
#[derive(Clone, Debug)]
pub struct LevelPlan {
    pub k: usize,
    /// `C(p, k)` — the paper's Fig. 7 series
    pub combinations: u64,
    /// bytes of the level's frontier arrays: `C(p,k)·(16 + k·12)`
    /// (q + r f64 per subset, bps f64 + bpm u32 per member)
    pub frontier_bytes: u64,
    /// true while `k·C(p,k)` is within `threshold·max` — the near-peak
    /// region the §5.3 extension spills
    pub is_peak: bool,
}

/// Whole-run plan for `p` variables.
#[derive(Clone, Debug)]
pub struct MemoryPlan {
    pub p: usize,
    /// Bytes per stored parent mask: 4 while `p` fits the narrow `u32`
    /// path ([`crate::MAX_VARS`]), 8 beyond it (the wide `u64` path).
    /// Every byte figure below scales with this.
    pub mask_bytes: u64,
    pub levels: Vec<LevelPlan>,
    /// peak of two adjacent frontiers + the `(1+mask)·2^p` sink tables
    pub peak_bytes: u64,
    /// the level index at the peak (paper: 15 for p = 29)
    pub peak_level: usize,
    /// baseline (Silander all-in-RAM):
    /// `2^p·8 + p·2^p·(8+mask) + 2^p·(9+mask)`
    pub baseline_bytes: u64,
}

/// Build the plan (pure arithmetic; `p ≤ 62` supported analytically —
/// beyond the exact-DP caps, for feasibility studies). The record width
/// follows the width the solver would dispatch to: `u32` masks up to
/// [`crate::MAX_VARS`], `u64` masks above.
pub fn memory_plan(p: usize, spill_threshold: f64) -> MemoryPlan {
    assert!((1..=62).contains(&p), "analytic planner supports p ≤ 62");
    let mask_bytes: u64 = if p <= crate::MAX_VARS { 4 } else { 8 };
    let binom = BinomTable::new(p);
    let weights = binom.frontier_weights(p);
    let max_weight = *weights.iter().max().unwrap();
    let frontier =
        |k: usize| -> u64 { binom.c(p, k) * (16 + (8 + mask_bytes) * k as u64) };
    let levels: Vec<LevelPlan> = (0..=p)
        .map(|k| LevelPlan {
            k,
            combinations: binom.c(p, k),
            frontier_bytes: frontier(k),
            is_peak: spill_threshold > 0.0
                && weights[k] as f64 >= spill_threshold * max_weight as f64,
        })
        .collect();
    let sink_bytes = (1 + mask_bytes) << p;
    let (peak_level, peak_bytes) = (0..p)
        .map(|k| (k + 1, frontier(k) + frontier(k + 1) + sink_bytes))
        .max_by_key(|&(_, b)| b)
        .unwrap();
    let baseline_bytes = (8u64 << p)
        + (8 + mask_bytes) * (p as u64) * (1u64 << p)
        + ((9 + mask_bytes) << p);
    MemoryPlan {
        p,
        mask_bytes,
        levels,
        peak_bytes,
        peak_level,
        baseline_bytes,
    }
}

impl MemoryPlan {
    /// Largest `p` whose planned peak fits a byte budget (paper §5.1:
    /// 16 GB ⇒ 26 for the baseline vs 28 for the proposed method). The
    /// scan crosses the u32→u64 record-width boundary at
    /// `p = MAX_VARS + 1`, so wide-path feasibility is priced honestly.
    pub fn max_p_within(budget_bytes: u64, baseline: bool) -> usize {
        let mut best = 0;
        for p in 1..=40 {
            let plan = memory_plan(p, 0.0);
            let need = if baseline {
                plan.baseline_bytes
            } else {
                plan.peak_bytes
            };
            if need <= budget_bytes {
                best = p;
            }
        }
        best
    }

    pub fn to_json(&self) -> Json {
        let mut levels = Json::arr();
        for l in &self.levels {
            levels = levels.push(
                Json::obj()
                    .set("k", l.k)
                    .set("combinations", l.combinations)
                    .set("frontier_bytes", l.frontier_bytes)
                    .set("is_peak", l.is_peak),
            );
        }
        Json::obj()
            .set("p", self.p)
            .set("mask_bytes", self.mask_bytes)
            .set("peak_bytes", self.peak_bytes)
            .set("peak_level", self.peak_level)
            .set("baseline_bytes", self.baseline_bytes)
            .set("levels", levels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_peak_level_for_p29_is_15() {
        // Paper §5.3: "Considering 29 variables … the 15th level will be
        // the peak of memory usage."
        let plan = memory_plan(29, 0.5);
        assert_eq!(plan.peak_level, 15);
    }

    #[test]
    fn fig7_combination_series_is_symmetric_and_peaks_mid() {
        let plan = memory_plan(29, 0.0);
        let combos: Vec<u64> = plan.levels.iter().map(|l| l.combinations).collect();
        assert_eq!(combos[0], 1);
        assert_eq!(combos[29], 1);
        for k in 0..=29 {
            assert_eq!(combos[k], combos[29 - k]);
        }
        let argmax = combos
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap()
            .0;
        assert!(argmax == 14 || argmax == 15);
    }

    #[test]
    fn paper_81gb_estimate_for_p29_level15_reproduced() {
        // §5.3: at p = 29 the level-15 parent-set vector alone is
        // C(28,14)·29·8 bytes = 8.6679 GB (the paper's accounting). Our
        // frontier counts k·C(p,k)·8 for bps, which equals the same
        // quantity: 15·C(29,15)·8 … check the paper's own figure via its
        // formula:
        let binom = BinomTable::new(29);
        let paper_bytes = binom.c(28, 14) * 29 * 8;
        let gb = paper_bytes as f64 / (1024.0 * 1024.0 * 1024.0);
        assert!((gb - 8.6679).abs() < 0.01, "{gb}");
    }

    #[test]
    fn proposed_beats_baseline_memory_for_all_p() {
        for p in 4..=30 {
            let plan = memory_plan(p, 0.0);
            assert!(
                plan.peak_bytes < plan.baseline_bytes,
                "p={p}: {} vs {}",
                plan.peak_bytes,
                plan.baseline_bytes
            );
        }
    }

    #[test]
    fn max_p_within_16gb_matches_paper_claims() {
        let budget = 16u64 << 30;
        let baseline = MemoryPlan::max_p_within(budget, true);
        let proposed = MemoryPlan::max_p_within(budget, false);
        // §5.1: "the upper limit is 26 variables, whereas our proposed
        // method can handle up to 28." Our accounting includes the
        // reconstruction tables the paper ignores, so allow ±1.
        assert!(
            (25..=27).contains(&baseline),
            "baseline max p = {baseline}"
        );
        assert!(
            (27..=29).contains(&proposed),
            "proposed max p = {proposed}"
        );
        assert!(proposed >= baseline + 2);
    }

    #[test]
    fn wide_plans_use_eight_byte_masks() {
        let narrow = memory_plan(30, 0.0);
        assert_eq!(narrow.mask_bytes, 4);
        let wide = memory_plan(31, 0.0);
        assert_eq!(wide.mask_bytes, 8);
        // frontier records are 16 bytes/member on the wide path
        let k = 10;
        assert_eq!(
            wide.levels[k].frontier_bytes,
            wide.levels[k].combinations * (16 + 16 * k as u64)
        );
        // p=33 (the spill-assisted target): plan is finite and the sink
        // tables price in 9-byte entries
        let p33 = memory_plan(33, 0.5);
        assert!(p33.levels.iter().any(|l| l.is_peak));
        assert!(p33.peak_bytes > (9u64 << 33));
    }

    #[test]
    fn spill_threshold_marks_near_peak_levels_only() {
        let plan = memory_plan(20, 0.9);
        let peaks: Vec<usize> = plan
            .levels
            .iter()
            .filter(|l| l.is_peak)
            .map(|l| l.k)
            .collect();
        assert!(!peaks.is_empty());
        assert!(peaks.len() < 8, "only near-peak levels spill: {peaks:?}");
        assert!(peaks.contains(&11) || peaks.contains(&10));
    }
}
