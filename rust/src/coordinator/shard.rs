//! Sharded frontier files + run manifest — the external-memory
//! coordinator behind [`crate::solver::solve_sharded`].
//!
//! The paper's single-traversal DP keeps two adjacent subset levels in
//! RAM; the §5.3 spill pushes the dominant best-parent vectors of peak
//! levels to disk but leaves the `16·C(p,p/2)`-byte `q`/`r` frontier and
//! the `(1+mask)·2^p` sink tables resident, which caps the wide exact
//! path at `p = `[`crate::MAX_VARS_WIDE`]. This module removes both
//! residents, Malone-style (external-memory frontier breadth-first
//! search): every level is partitioned into [`ShardSpec::shards`]
//! equal colex-rank ranges — for power-of-two level sizes exactly the
//! **top `log2(shards)` bits of the colex rank** — and each shard streams
//! its third of the frontier (`.bps`, `.qr`, `.sink` files, one spill
//! writer per shard) through a fixed-size batch buffer. The next level
//! reads the previous one through per-worker window caches
//! ([`ShardedLevelReader`]), and reconstruction random-accesses the
//! per-level `.sink` files instead of a `2^p` in-RAM table, so peak RAM
//! is `O(shards · (batch + cache))` — per-shard frontier, not per-level.
//!
//! A `manifest.json` in the run directory records the run's identity
//! (`p`, shard count, mask width, score, dataset fingerprint) and the
//! highest *committed* level. The manifest is rewritten atomically
//! (write-temp-then-rename) after each level's shards all finish, which
//! makes a killed run resumable at the last completed level:
//! `--resume <dir>` revalidates the manifest and every surviving shard
//! header, then continues the sweep without recomputing finished levels.
//!
//! Every durable operation — manifest commit, shard-stream writes,
//! windowed reads — goes through the pluggable
//! [`crate::coordinator::storage::StorageBackend`]
//! ([`ShardOptions::backend`]): the POSIX backend reproduces the
//! pre-trait file behavior byte for byte, and the object backend speaks
//! S3 semantics against the same key layout.
//!
//! All files share the 16-byte v1 header of [`crate::coordinator::spill`]
//! (magic, version, mask width, level, record kind). The byte-level
//! specification — header layout, the three record kinds, the manifest
//! schema, and a worked hex example — lives in
//! [`docs/FORMATS.md`](https://github.com/paper-repo-growth/bnsl/blob/main/docs/FORMATS.md)
//! (in-tree: `docs/FORMATS.md`).

use super::spill::{
    decode_header, encode_header, record_bytes, HEADER, KIND_BPS, KIND_PRN, KIND_QR, KIND_SINK,
};
use super::storage::{
    make_backend, BackendKind, CreateOutcome, PosixBackend, RandomRead, ShardStream,
    SharedBackend,
};
use crate::bitset::{colex_rank, BinomTable, VarMask};
use crate::bn::Dag;
use crate::data::Dataset;
use crate::score::ScoreKind;
use crate::solver::PruneStamp;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::cell::{Cell, RefCell};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

// Cache geometry is shared with the §5.3 spill reader so the two
// direct-mapped window caches cannot drift apart.
pub(crate) use super::spill::{SLOTS, WINDOW};

/// Manifest format version written by this binary. Version 2 (ISSUE 3)
/// added the informational `hosts` field alongside the cluster claim
/// ledger ([`crate::coordinator::cluster`]); version 3 (ISSUE 8) added
/// the optional prune stamp (`prune_incumbent` / `prune_ub_hash`) that
/// marks a run's shard files as prune-format (slim `.bps`/`.sink`
/// streams + `.prn` presence sidecars). Older manifests are still read:
/// absent fields mean a dense-format run.
const MANIFEST_FORMAT: u64 = 3;
/// Oldest manifest format this reader still understands.
const MANIFEST_FORMAT_MIN: u64 = 1;

/// Bytes of one `.qr` record: little-endian `f64` `log Q` + `f64` `log R`.
pub(crate) const QR_RECORD: usize = 16;

/// Colex ranks covered by one `.prn` presence block.
pub(crate) const PRN_BLOCK: usize = 4096;

/// Bytes of one `.prn` record: little-endian `u64` survivor count
/// *before* the block + a [`PRN_BLOCK`]-bit presence bitmap.
pub(crate) const PRN_RECORD: usize = 8 + PRN_BLOCK / 8;

/// Bounded patience for manifest reads on the resume/join *entry* path
/// of backends whose reads may lag writes
/// ([`crate::coordinator::storage::StorageBackend::reads_may_lag`]):
/// one unlucky GET inside a read-after-write window must not turn a
/// valid `--resume` into a fatal "nothing to resume". (Poll loops
/// elsewhere — the cluster barrier, `committed_level_patient` — carry
/// their own grace windows.)
const ENTRY_GRACE: Duration = Duration::from_secs(10);
const ENTRY_POLL: Duration = Duration::from_millis(50);

/// Marker embedded in [`ShardRun::open_on`]'s missing-manifest error.
/// `validate_resume` keys its transient-retry decision on it: a lagged
/// GET can only make the manifest look *absent* — every other failure
/// (backend-binding mismatch, corrupt JSON, unsupported format) is
/// deterministic and must surface immediately, not after a grace spin.
const NO_MANIFEST: &str = "no manifest found";

/// Bytes of one `.sink` record at width `M`: sink-variable byte + mask.
#[inline]
pub(crate) const fn sink_record_bytes<M: VarMask>() -> usize {
    1 + M::BYTES
}

/// Cache-slot budget per open shard file: the fixed [`SLOTS`] total is
/// divided across the level's shards so a reader's aggregate cache does
/// not grow with the shard count.
pub(crate) fn slot_cap(shards: usize) -> usize {
    (SLOTS / shards).max(1)
}

/// Resident bytes of the window cache a reader opens over `entries`
/// records of `record` bytes in one of `shards` shard files (shared with
/// the memory planner so [`crate::coordinator::plan`] prices exactly
/// what the reader allocates).
pub(crate) fn reader_cache_bytes(entries: usize, record: usize, shards: usize) -> usize {
    let slots = slot_cap(shards).min(entries.div_ceil(WINDOW)).max(1);
    slots * WINDOW * record + slots * 8
}

/// Extra handle headroom a cluster host needs on top of the worker-pool
/// read/write handles: transient claim / done-marker / finish-marker /
/// manifest-poll opens ([`crate::coordinator::cluster`]). Small but real
/// — the ledger is touched from inside the level loop, so budgeting it
/// up front keeps the preflight honest.
pub(crate) const CLUSTER_FD_MARGIN: u64 = 16;

/// Per-host open-file budget of a sharded run: every worker holds `.qr`
/// + `.bps` + `.prn` read handles for all previous-level shards plus its
/// own four writer streams, plus a fixed process margin; cluster mode
/// adds the claim-ledger headroom. Dense-format runs open fewer handles
/// (no `.prn` sidecars), but the budget prices the prune-format worst
/// case uniformly so a run can't pass preflight and then die on EMFILE
/// when pruning is on. Shared between the solver preflights and
/// [`crate::coordinator::plan::sharded_plan`], so `bnsl info` prices
/// exactly what the drivers check.
pub fn fd_budget(workers: usize, shards: usize, cluster: bool) -> u64 {
    let base = workers as u64 * (3 * shards as u64 + 4) + 32;
    if cluster {
        base + CLUSTER_FD_MARGIN
    } else {
        base
    }
}

/// Soft `RLIMIT_NOFILE` via `/proc/self/limits` (`None` off Linux or if
/// unreadable) — the sharded driver preflights its per-worker handle
/// budget against this instead of dying mid-level on EMFILE.
pub(crate) fn fd_soft_limit() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/limits").ok()?;
    let line = text.lines().find(|l| l.starts_with("Max open files"))?;
    // "Max open files   <soft>   <hard>   files"
    line.split_whitespace().nth(3)?.parse().ok()
}

/// Tuning knobs for one sharded run (see [`crate::solver::solve_sharded`]).
#[derive(Clone, Debug)]
pub struct ShardOptions {
    /// Number of frontier shards per level. Must be a power of two
    /// (shards are keyed by the top bits of the colex rank); `0` means
    /// "take the count from the manifest" (resume).
    pub shards: usize,
    /// Worker threads draining the shard queue; `0` = one per shard,
    /// capped at the machine's available parallelism (each worker holds
    /// read handles for every previous-level shard, so more workers than
    /// cores only burns file descriptors).
    pub workers: usize,
    /// Subsets scored per engine batch within each shard.
    pub batch: usize,
    /// Run directory: manifest + per-level shard files.
    pub dir: PathBuf,
    /// Checkpoint hook: commit levels up to and including this one, then
    /// return [`crate::solver::ShardOutcome::Checkpointed`] instead of
    /// finishing. Drives the kill-and-resume tests and time-boxed solves.
    pub stop_after_level: Option<usize>,
    /// Keep every level's `.bps`/`.qr` files instead of pruning levels
    /// that are no longer needed for resume (debugging aid).
    pub keep_levels: bool,
    /// Declared cluster size (informational, recorded in the v2 manifest;
    /// 1 for single-host runs). The claim ledger is elastic — hosts may
    /// join or vanish — so this is *not* validated on resume.
    pub hosts: usize,
    /// Storage backend the run coordinates through (CLI `--backend`):
    /// POSIX filesystem semantics (the default) or an S3-style object
    /// store ([`crate::coordinator::storage`]). All hosts of one run
    /// must pick the same backend.
    pub backend: BackendKind,
    /// Cooperative stop flag ([`crate::solver::CancelToken`]): when it
    /// fires, the run commits the level it is on and returns
    /// [`crate::solver::ShardOutcome::Checkpointed`] — exactly like
    /// [`ShardOptions::stop_after_level`], but triggered asynchronously
    /// (job cancellation, SIGTERM drain) instead of at a pre-declared
    /// level. The default token never fires.
    pub cancel: crate::solver::CancelToken,
    /// Order-graph pruning ([`crate::solver::bounds`]): when resolved,
    /// the run is created in prune format (slim `.bps`/`.sink` streams
    /// plus `.prn` presence sidecars) and its bound/incumbent stamp is
    /// recorded in the manifest so every resume provably reruns the
    /// same pruned sweep. `Off` (the default) keeps the dense format.
    pub prune: crate::solver::PruneMode,
}

impl Default for ShardOptions {
    fn default() -> ShardOptions {
        ShardOptions {
            shards: 1,
            workers: 0,
            batch: 1024,
            dir: PathBuf::from("bnsl_shards"),
            stop_after_level: None,
            keep_levels: false,
            hosts: 1,
            backend: BackendKind::Posix,
            cancel: crate::solver::CancelToken::new(),
            prune: crate::solver::PruneMode::Off,
        }
    }
}

/// Partition of one level's `C(p,k)` colex ranks into equal contiguous
/// ranges. With a power-of-two level size the shard index is literally
/// the top `log2(shards)` bits of the rank; ragged sizes round the range
/// width up, leaving trailing shards short or empty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Level size `C(p,k)`.
    pub size: u64,
    /// Shard count (power of two).
    pub shards: usize,
    /// Ranks per shard: `ceil(size / shards)`.
    pub width: u64,
}

impl ShardSpec {
    pub fn new(size: u64, shards: usize) -> ShardSpec {
        assert!(shards >= 1 && shards.is_power_of_two());
        ShardSpec {
            size,
            shards,
            width: size.div_ceil(shards as u64).max(1),
        }
    }

    /// Global rank range `[lo, hi)` of shard `s` (empty when `lo >= hi`).
    pub fn bounds(&self, s: usize) -> (u64, u64) {
        let lo = (s as u64 * self.width).min(self.size);
        let hi = ((s as u64 + 1) * self.width).min(self.size);
        (lo, hi)
    }

    /// Entries in shard `s`.
    pub fn entries(&self, s: usize) -> u64 {
        let (lo, hi) = self.bounds(s);
        hi - lo
    }

    /// Shard + shard-local offset of a global rank.
    #[inline]
    pub fn locate(&self, rank: u64) -> (usize, u64) {
        debug_assert!(rank < self.size);
        ((rank / self.width) as usize, rank % self.width)
    }
}

/// Stable identity of (dataset, score): resuming against different data
/// or a different scoring function is rejected up front instead of
/// producing a silently wrong network. FNV-1a over the dataset shape,
/// arities, raw column bytes and the score's debug form.
pub fn run_fingerprint(data: &Dataset, kind: ScoreKind) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(&(data.p() as u64).to_le_bytes());
    eat(&(data.n() as u64).to_le_bytes());
    eat(data.arities());
    for v in 0..data.p() {
        eat(data.column(v));
    }
    eat(format!("{kind:?}").as_bytes());
    format!("{h:016x}")
}

/// One sharded run rooted at a directory: identity + committed progress.
///
/// The manifest is the durability boundary. A level exists iff
/// `completed >= Some(k)`; files of uncommitted levels are ignored (and
/// overwritten) by the next attempt.
#[derive(Clone, Debug)]
pub struct ShardRun {
    store: SharedBackend,
    dir: PathBuf,
    pub p: usize,
    pub n: usize,
    pub shards: usize,
    pub mask_bytes: usize,
    pub score: String,
    pub fingerprint: String,
    /// Declared cluster size when the run was created (informational;
    /// 1 for single-host runs and for v1 manifests).
    pub hosts: usize,
    /// Storage backend the run is coordinated through, as recorded in
    /// the manifest (pre-PR-4 manifests default to POSIX). A run
    /// directory is **bound** to its backend: liveness semantics differ
    /// (mtime vs. heartbeat metadata), so a host joining through the
    /// other backend would mis-judge live claims as stale and
    /// continually steal them — [`ShardRun::open_on`] rejects the
    /// mismatch up front instead, for every resume, join and raw open.
    pub backend: BackendKind,
    /// Prune stamp recorded when the run was created (`None` = dense
    /// format). `Some` marks every level-`k ≥ 1` shard as prune-format —
    /// slim `.bps`/`.sink` streams plus a `.prn` presence sidecar — and
    /// pins the exact bounds + incumbent: the DP's inter-level
    /// dependencies make a half-pruned run unreadable, so a resume whose
    /// recomputed stamp differs is rejected instead of silently mixing
    /// two different pruned sweeps ([`crate::solver::bounds`]).
    pub prune: Option<PruneStamp>,
    /// Highest committed level (`None` before level 0 commits).
    pub completed: Option<usize>,
}

impl ShardRun {
    /// Start a fresh run, or resume the one already rooted at
    /// `options.dir`, on the backend `options.backend` selects. A fresh
    /// run requires `options.shards >= 1`; a resume
    /// (`options.shards == 0` or a matching explicit count) revalidates
    /// `p`, mask width, score and dataset fingerprint against the
    /// manifest and rejects mismatches by name. `prune` is the stamp a
    /// *fresh* run records (prune-format shard files); on resume the
    /// manifest's recorded stamp wins and the caller reconciles it
    /// against its own bounds ([`crate::solver::solve_sharded`]).
    #[allow(clippy::too_many_arguments)]
    pub fn open_or_create(
        options: &ShardOptions,
        p: usize,
        n: usize,
        mask_bytes: usize,
        score: &str,
        fingerprint: &str,
        prune: Option<PruneStamp>,
    ) -> Result<ShardRun> {
        let store = make_backend(options.backend, &options.dir)?;
        ShardRun::open_or_create_on(store, options, p, n, mask_bytes, score, fingerprint, prune)
    }

    /// [`ShardRun::open_or_create`] on an already-constructed backend
    /// (the cluster init path builds the backend first for its lock).
    #[allow(clippy::too_many_arguments)]
    pub fn open_or_create_on(
        store: SharedBackend,
        options: &ShardOptions,
        p: usize,
        n: usize,
        mask_bytes: usize,
        score: &str,
        fingerprint: &str,
        prune: Option<PruneStamp>,
    ) -> Result<ShardRun> {
        if store.exists("manifest.json")? {
            return ShardRun::validate_resume(store, options, p, mask_bytes, score, fingerprint);
        }
        if options.shards == 0 {
            // explicit resume intent: the caller asserts a run exists
            // here, so on a lagging backend one false existence probe
            // must not produce the misleading "nothing to resume" —
            // re-probe within the entry grace window first
            if store.reads_may_lag() {
                let start = Instant::now();
                while start.elapsed() <= ENTRY_GRACE {
                    if store.exists("manifest.json")? {
                        return ShardRun::validate_resume(
                            store,
                            options,
                            p,
                            mask_bytes,
                            score,
                            fingerprint,
                        );
                    }
                    std::thread::sleep(ENTRY_POLL);
                }
            }
            bail!(
                "{}: nothing to resume (no manifest.json); start a run with --shards N",
                store.root()
            );
        }
        if !options.shards.is_power_of_two() {
            bail!(
                "--shards {} is not a power of two; shards are keyed by the \
                 top bits of the colex rank (try {} or {})",
                options.shards,
                options.shards.next_power_of_two() >> 1,
                options.shards.next_power_of_two()
            );
        }
        store.ensure_root()?;
        let run = ShardRun {
            dir: options.dir.clone(),
            store,
            p,
            n,
            shards: options.shards,
            mask_bytes,
            score: score.to_string(),
            fingerprint: fingerprint.to_string(),
            hosts: options.hosts.max(1),
            backend: options.backend,
            prune,
            completed: None,
        };
        // conditional create, not an unconditional publish: the
        // existence probe above may have *lagged* (an object store's
        // read-after-write window, injectable via stale_reads) or lost
        // a same-directory race — and a manifest that turns out to
        // exist is a run whose committed progress must never be
        // overwritten with a fresh `levels_complete = -1`. On
        // AlreadyExists we take the ordinary validate-and-resume path
        // against the manifest that was there all along.
        let body = run.manifest_doc().to_pretty();
        match run
            .store
            .publish_doc_if_absent("manifest.json", body.as_bytes())?
        {
            CreateOutcome::Created => Ok(run),
            CreateOutcome::AlreadyExists => ShardRun::validate_resume(
                run.store,
                options,
                p,
                mask_bytes,
                score,
                fingerprint,
            ),
        }
    }

    /// The resume half of [`ShardRun::open_or_create_on`]: open the
    /// existing manifest and reject identity mismatches by name (`n` is
    /// informational in the manifest and not part of the identity).
    /// Callers reach this knowing a manifest is (or was just observed)
    /// there, so on a lagging backend an unreadable manifest is retried
    /// within the entry grace window before the error is fatal.
    fn validate_resume(
        store: SharedBackend,
        options: &ShardOptions,
        p: usize,
        mask_bytes: usize,
        score: &str,
        fingerprint: &str,
    ) -> Result<ShardRun> {
        // retry only the missing-manifest case: that is the one failure
        // a lagged GET can fabricate; deterministic errors (binding
        // mismatch, corrupt JSON, bad format) surface immediately
        let transient =
            |e: &anyhow::Error| -> bool { e.to_string().contains(NO_MANIFEST) };
        let run = match ShardRun::open_on(store.clone()) {
            Ok(run) => run,
            Err(first) => {
                if !store.reads_may_lag() || !transient(&first) {
                    return Err(first);
                }
                let start = Instant::now();
                loop {
                    std::thread::sleep(ENTRY_POLL);
                    match ShardRun::open_on(store.clone()) {
                        Ok(run) => break run,
                        Err(e) if !transient(&e) || start.elapsed() > ENTRY_GRACE => {
                            return Err(e)
                        }
                        Err(_) => {}
                    }
                }
            }
        };
        let manifest = run.manifest_name();
        let reject = |field: &str, manifest_has: &str, caller_has: &str| -> anyhow::Error {
            anyhow::anyhow!(
                "{manifest}: cannot resume — manifest records {field} = {manifest_has} \
                 but this invocation has {field} = {caller_has}; use a fresh \
                 --shard-dir for a different run"
            )
        };
        if run.p != p {
            return Err(reject("p", &run.p.to_string(), &p.to_string()));
        }
        if run.mask_bytes != mask_bytes {
            return Err(reject(
                "mask_bytes",
                &run.mask_bytes.to_string(),
                &mask_bytes.to_string(),
            ));
        }
        if run.score != score {
            return Err(reject("score", &run.score, score));
        }
        if run.fingerprint != fingerprint {
            return Err(reject("data fingerprint", &run.fingerprint, fingerprint));
        }
        if options.shards != 0 && options.shards != run.shards {
            return Err(reject(
                "shards",
                &run.shards.to_string(),
                &options.shards.to_string(),
            ));
        }
        // (backend mismatches never get this far: open_on rejects a
        // store whose kind differs from the manifest's recorded
        // binding, covering resumes, cluster joins and raw opens
        // through the one choke point)
        Ok(run)
    }

    /// The run directory (manifest, shard files, and — in cluster mode —
    /// the claim ledger).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The storage backend every durable operation of this run goes
    /// through.
    pub fn store(&self) -> &SharedBackend {
        &self.store
    }

    /// `root/manifest.json`, for error messages.
    fn manifest_name(&self) -> String {
        format!("{}/manifest.json", self.store.root())
    }

    /// Load an existing run's manifest through a POSIX handle (resume
    /// entry point for POSIX-bound runs; backend-explicit callers use
    /// [`ShardRun::open_on`]). A run bound to another backend is
    /// rejected with the `--backend` flag to use.
    pub fn open(dir: &Path) -> Result<ShardRun> {
        ShardRun::open_on(Arc::new(PosixBackend::new(dir)))
    }

    /// Load an existing run's manifest through `store`.
    pub fn open_on(store: SharedBackend) -> Result<ShardRun> {
        let name = format!("{}/manifest.json", store.root());
        let Some(bytes) = store.read_doc("manifest.json")? else {
            bail!("{name}: {NO_MANIFEST} (nothing to resume)");
        };
        let text = String::from_utf8(bytes)
            .map_err(|_| anyhow::anyhow!("{name}: manifest is not UTF-8"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{name}: invalid JSON: {e}"))?;
        fn field<'a>(doc: &'a Json, name: &str, key: &str) -> Result<&'a Json> {
            doc.get(key)
                .ok_or_else(|| anyhow::anyhow!("{name}: missing field '{key}'"))
        }
        fn as_usize(doc: &Json, name: &str, key: &str) -> Result<usize> {
            field(doc, name, key)?
                .as_u64()
                .map(|v| v as usize)
                .ok_or_else(|| anyhow::anyhow!("{name}: field '{key}' not a count"))
        }
        fn as_string(doc: &Json, name: &str, key: &str) -> Result<String> {
            field(doc, name, key)?
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("{name}: field '{key}' not a string"))
        }
        let format = field(&doc, &name, "format")?.as_u64().unwrap_or(0);
        if !(MANIFEST_FORMAT_MIN..=MANIFEST_FORMAT).contains(&format) {
            bail!(
                "{name}: manifest format {format} unsupported (reader speaks \
                 {MANIFEST_FORMAT_MIN}..={MANIFEST_FORMAT})"
            );
        }
        let completed = match field(&doc, &name, "levels_complete")?.as_i64() {
            Some(v) if v >= 0 => Some(v as usize),
            Some(_) => None,
            None => bail!("{name}: field 'levels_complete' not an integer"),
        };
        let run = ShardRun {
            dir: PathBuf::from(store.root()),
            p: as_usize(&doc, &name, "p")?,
            n: as_usize(&doc, &name, "n")?,
            shards: as_usize(&doc, &name, "shards")?,
            mask_bytes: as_usize(&doc, &name, "mask_bytes")?,
            score: as_string(&doc, &name, "score")?,
            fingerprint: as_string(&doc, &name, "fingerprint")?,
            // v2 field; v1 manifests were single-host by construction
            hosts: doc
                .get("hosts")
                .and_then(Json::as_u64)
                .map_or(1, |h| (h as usize).max(1)),
            // optional field (PR 4); runs recorded before it existed
            // were POSIX by construction
            backend: match doc.get("backend").and_then(Json::as_str) {
                None => BackendKind::Posix,
                Some(recorded) => BackendKind::parse(recorded).ok_or_else(|| {
                    anyhow::anyhow!(
                        "{name}: manifest records unknown storage backend \
                         '{recorded}' (this reader speaks posix|object)"
                    )
                })?,
            },
            // v3 fields; absent (older manifests, unpruned runs) means
            // the dense shard format
            prune: {
                let hex_field = |key: &str| -> Result<Option<u64>> {
                    match doc.get(key) {
                        None => Ok(None),
                        Some(v) => {
                            let s = v.as_str().ok_or_else(|| {
                                anyhow::anyhow!("{name}: field '{key}' not a string")
                            })?;
                            u64::from_str_radix(s, 16).map(Some).map_err(|_| {
                                anyhow::anyhow!(
                                    "{name}: field '{key}' is not a 64-bit hex stamp"
                                )
                            })
                        }
                    }
                };
                match (hex_field("prune_incumbent")?, hex_field("prune_ub_hash")?) {
                    (Some(incumbent_bits), Some(ub_hash)) => Some(PruneStamp {
                        incumbent_bits,
                        ub_hash,
                    }),
                    (None, None) => None,
                    _ => bail!(
                        "{name}: manifest has one of 'prune_incumbent' / \
                         'prune_ub_hash' but not the other — the run \
                         directory is corrupt"
                    ),
                }
            },
            completed,
            store,
        };
        if !run.shards.is_power_of_two() || run.shards == 0 {
            bail!(
                "{name}: manifest shard count {} is not a power of two",
                run.shards
            );
        }
        if let Some(k) = run.completed {
            if k > run.p {
                bail!(
                    "{name}: manifest claims level {k} complete but p = {}",
                    run.p
                );
            }
        }
        // a run directory is bound to one backend: the two judge claim
        // liveness by different stamps (mtime vs. heartbeat metadata),
        // so coordinating an object-bound run through a POSIX handle
        // (or vice versa) would spuriously steal live claims forever.
        // Rejecting here — the one choke point every resume, cluster
        // join and raw open goes through — makes the mix unrepresentable.
        if run.backend != run.store.kind() {
            bail!(
                "{name}: this run is bound to the '{}' storage backend \
                 but was opened through '{}'; pass --backend {} (a run \
                 directory is bound to one backend — all hosts and \
                 resumes must agree)",
                run.backend.name(),
                run.store.kind().name(),
                run.backend.name()
            );
        }
        Ok(run)
    }

    /// Atomically rewrite the manifest from this handle's in-memory
    /// state without advancing it — the cluster barrier's repair hook
    /// for a manifest that regressed when a stalled committer's rename
    /// landed late (see `coordinator::cluster::commit_checked`).
    pub(crate) fn rewrite_manifest(&self) -> Result<()> {
        self.write_manifest()
    }

    /// The manifest document for this handle's current state (shared by
    /// the unconditional commit rewrite and the conditional creation).
    fn manifest_doc(&self) -> Json {
        let mut doc = Json::obj()
            .set("format", MANIFEST_FORMAT)
            .set("p", self.p)
            .set("n", self.n)
            .set("shards", self.shards)
            .set("mask_bytes", self.mask_bytes)
            .set("score", self.score.as_str())
            .set("fingerprint", self.fingerprint.as_str())
            .set("hosts", self.hosts)
            .set("backend", self.backend.name());
        if let Some(stamp) = self.prune {
            doc = doc
                .set("prune_incumbent", format!("{:016x}", stamp.incumbent_bits))
                .set("prune_ub_hash", format!("{:016x}", stamp.ub_hash));
        }
        doc.set(
            "levels_complete",
            self.completed.map(|k| k as i64).unwrap_or(-1),
        )
    }

    fn write_manifest(&self) -> Result<()> {
        // publish_doc is the durable atomic replace: readers see the old
        // or the new manifest, never a mixture, and concurrent writers
        // (a benign cluster commit race — the contents are identical)
        // cannot clobber each other's in-flight write. On POSIX that is
        // write-temp(+pid+seq)-fsync-rename(+dir fsync); on an object
        // store a whole-object PUT.
        self.store
            .publish_doc("manifest.json", self.manifest_doc().to_pretty().as_bytes())
    }

    /// Durably mark level `k` complete (atomic manifest rewrite). All of
    /// the level's shard files must be flushed before this is called.
    /// Levels commit strictly in order: committing a level at or below
    /// `completed` (a double commit) or skipping ahead is an error, not a
    /// silent rewrite — the cluster barrier relies on this to reject a
    /// confused committer.
    pub fn commit_level(&mut self, k: usize) -> Result<()> {
        let expect = self.completed.map_or(0, |c| c + 1);
        if k != expect {
            match self.completed {
                Some(c) if k <= c => bail!(
                    "{}: level {k} is already committed (double commit \
                     rejected; levels_complete = {c})",
                    self.manifest_name()
                ),
                _ => bail!(
                    "{}: cannot commit level {k} out of order — the next \
                     committable level is {expect}",
                    self.manifest_name()
                ),
            }
        }
        self.completed = Some(k);
        self.write_manifest()
    }

    /// Shard partition of level `k`.
    pub fn spec(&self, binom: &BinomTable, k: usize) -> ShardSpec {
        ShardSpec::new(binom.c(self.p, k), self.shards)
    }

    /// Key of one shard stream: `level_{k}_shard_{s}.{ext}` (identical
    /// to the POSIX file name — the object-key layout mirrors the file
    /// layout, see `docs/FORMATS.md`).
    pub fn shard_key(&self, k: usize, s: usize, ext: &str) -> String {
        format!("level_{k:02}_shard_{s:04}.{ext}")
    }

    /// Path of one shard file under the run root (display / test
    /// convenience; I/O goes through [`ShardRun::store`] by key).
    pub fn shard_file(&self, k: usize, s: usize, ext: &str) -> PathBuf {
        self.dir.join(self.shard_key(k, s, ext))
    }

    /// Drop the `.bps`/`.qr` files of a level that is no longer needed
    /// for resume (its successor has committed). `.sink` files stay:
    /// reconstruction reads one record per level at the very end — and
    /// so do `.prn` presence sidecars, which reconstruction needs to map
    /// a colex rank to its slot in the slim `.sink` stream.
    pub fn prune_level(&self, k: usize) {
        for s in 0..self.shards {
            let _ = self.store.delete(&self.shard_key(k, s, "bps"));
            let _ = self.store.delete(&self.shard_key(k, s, "qr"));
        }
    }
}

/// Receives one sink record per subset, in colex order — the level sweep
/// is generic over whether sinks land in the in-RAM `2^p` tables
/// (unsharded solver) or a per-shard stream buffer ([`SinkBuf`]).
///
/// Exactly one of [`SinkOut::put`] / [`SinkOut::put_pruned`] is called
/// per subset: `put_pruned` marks a subset whose records the bounds
/// layer ([`crate::solver::bounds`]) proved dominated, so prune-aware
/// sinks can skip the record while keeping the colex cursor aligned.
/// The default is a no-op — the resident solver's dense tables simply
/// never read the pruned entries.
pub trait SinkOut<M: VarMask> {
    fn put(&mut self, mask: M, sink: u8, pmask: M);
    fn put_pruned(&mut self, _mask: M) {}
}

/// Buffered sink records for one shard batch (flushed to the `.sink`
/// file by [`ShardWriterSet::append`]), plus the batch's per-subset
/// presence flags (`0` = emitted, `1` = pruned) that drive the slim
/// prune-format streams.
pub struct SinkBuf<M: VarMask> {
    buf: Vec<u8>,
    flags: Vec<u8>,
    _width: PhantomData<M>,
}

impl<M: VarMask> Default for SinkBuf<M> {
    fn default() -> SinkBuf<M> {
        SinkBuf {
            buf: Vec::new(),
            flags: Vec::new(),
            _width: PhantomData,
        }
    }
}

impl<M: VarMask> SinkOut<M> for SinkBuf<M> {
    #[inline]
    fn put(&mut self, _mask: M, sink: u8, pmask: M) {
        self.flags.push(0);
        self.buf.push(sink);
        self.buf
            .extend_from_slice(&pmask.to_u64().to_le_bytes()[..M::BYTES]);
    }

    #[inline]
    fn put_pruned(&mut self, _mask: M) {
        self.flags.push(1);
    }
}

/// The one-spill-writer-per-shard bundle: `.bps` + `.qr` + `.sink`
/// streams for one (level, shard) pair, appended batch by batch so a
/// shard's frontier never materialises in RAM.
///
/// Single-host runs write the canonical `level_*_shard_*.{ext}` streams
/// directly ([`ShardWriterSet::create`]). Cluster hosts write *staged*
/// streams (`.{ext}.host-…` — [`ShardWriterSet::create_staged`]) that
/// [`ShardWriterSet::finish`] publishes under the canonical keys only
/// after the bytes are durable (POSIX: fsync + rename; object store:
/// completed upload + server-side copy), so a host whose claim was
/// reclaimed mid-write (a "zombie") can never leave a truncated
/// canonical stream: either its publish never happens, or it atomically
/// publishes bytes that are bit-identical to the reclaimer's (the sweep
/// is deterministic).
pub struct ShardWriterSet<M: VarMask> {
    bps: Box<dyn ShardStream>,
    qr: Box<dyn ShardStream>,
    sink: Box<dyn ShardStream>,
    /// Presence-sidecar writer, only for prune-format runs at `k ≥ 1`
    /// (level 0 has the single always-present empty set and no `.bps`).
    prn: Option<PrnWriter>,
    /// Best-parent records per subset (the level `k`).
    k: usize,
    entries: u64,
    bytes: u64,
    _width: PhantomData<M>,
}

/// Streams the `.prn` presence sidecar of one prune-format shard: one
/// [`PRN_RECORD`]-byte block per [`PRN_BLOCK`] appended ranks, carrying
/// the survivor count before the block and the block's presence bitmap
/// (a partial tail block is flushed by [`ShardWriterSet::finish`]).
struct PrnWriter {
    stream: Box<dyn ShardStream>,
    bits: [u8; PRN_RECORD - 8],
    fill: usize,
    survivors: u64,
}

impl PrnWriter {
    /// Record one rank's presence; returns the bytes flushed (0 unless
    /// this append completed a block).
    fn push(&mut self, present: bool) -> Result<u64> {
        if present {
            self.bits[self.fill / 8] |= 1 << (self.fill % 8);
        }
        self.fill += 1;
        if self.fill == PRN_BLOCK {
            return self.flush_block();
        }
        Ok(0)
    }

    fn flush_block(&mut self) -> Result<u64> {
        self.stream.write_all(&self.survivors.to_le_bytes())?;
        self.stream.write_all(&self.bits)?;
        self.survivors += self
            .bits
            .iter()
            .map(|b| b.count_ones() as u64)
            .sum::<u64>();
        self.bits = [0u8; PRN_RECORD - 8];
        self.fill = 0;
        Ok(PRN_RECORD as u64)
    }
}

impl<M: VarMask> ShardWriterSet<M> {
    /// Write the canonical shard files directly (single-host path).
    pub fn create(run: &ShardRun, k: usize, s: usize) -> Result<ShardWriterSet<M>> {
        ShardWriterSet::create_inner(run, k, s, None)
    }

    /// Write host-unique staged streams, atomically published under the
    /// canonical keys by [`ShardWriterSet::finish`] (cluster path).
    /// `tag` must be unique per writing process (e.g. `host-0003-71234`).
    pub fn create_staged(
        run: &ShardRun,
        k: usize,
        s: usize,
        tag: &str,
    ) -> Result<ShardWriterSet<M>> {
        ShardWriterSet::create_inner(run, k, s, Some(tag))
    }

    fn create_inner(
        run: &ShardRun,
        k: usize,
        s: usize,
        tag: Option<&str>,
    ) -> Result<ShardWriterSet<M>> {
        let mut open = |ext: &str, kind: u8| -> Result<Box<dyn ShardStream>> {
            let key = run.shard_key(k, s, ext);
            let mut w = run.store.create_stream(&key, tag)?;
            w.write_all(&encode_header(M::BYTES as u8, k as u8, kind))
                .with_context(|| format!("writing header of {key}"))?;
            Ok(w)
        };
        let bps = open("bps", KIND_BPS)?;
        let qr = open("qr", KIND_QR)?;
        let sink = open("sink", KIND_SINK)?;
        // prune-format runs carry a presence sidecar for every k ≥ 1
        // level — even a level nothing was pruned from, so readers never
        // have to guess which format a file is in
        let prn = if run.prune.is_some() && k >= 1 {
            Some(PrnWriter {
                stream: open("prn", KIND_PRN)?,
                bits: [0u8; PRN_RECORD - 8],
                fill: 0,
                survivors: 0,
            })
        } else {
            None
        };
        let streams = if prn.is_some() { 4 } else { 3 };
        Ok(ShardWriterSet {
            bps,
            qr,
            sink,
            prn,
            k,
            entries: 0,
            bytes: streams * HEADER as u64,
            _width: PhantomData,
        })
    }

    /// Append one computed batch: `take` subsets' `q`/`r`, their
    /// `take·k` best-parent records, and the batch's buffered sink
    /// records (cleared after the flush).
    ///
    /// Dense runs write everything. Prune-format runs consult the
    /// batch's presence flags ([`SinkBuf::put_pruned`]): `.qr` stays
    /// dense (every predecessor's `log Q` is read by the next level,
    /// and a pruned subset's `log R = −∞` is one plain record), while
    /// the `.bps` rows of pruned subsets are skipped — their slots are
    /// reconstructed as `−∞` by the reader — and the `.sink` buffer is
    /// already slim because `put_pruned` buffers no record.
    pub fn append(
        &mut self,
        q: &[f64],
        r: &[f64],
        bps: &[f64],
        bpm: &[M],
        sinks: &mut SinkBuf<M>,
    ) -> Result<()> {
        debug_assert_eq!(q.len(), r.len());
        debug_assert_eq!(bps.len(), bpm.len());
        for i in 0..q.len() {
            self.qr.write_all(&q[i].to_le_bytes())?;
            self.qr.write_all(&r[i].to_le_bytes())?;
        }
        let mut bps_written = 0usize;
        match &mut self.prn {
            None => {
                for i in 0..bps.len() {
                    self.bps.write_all(&bps[i].to_le_bytes())?;
                    self.bps
                        .write_all(&bpm[i].to_u64().to_le_bytes()[..M::BYTES])?;
                }
                bps_written = bps.len();
            }
            Some(prn) => {
                debug_assert_eq!(
                    sinks.flags.len(),
                    q.len(),
                    "prune-format append needs one presence flag per subset"
                );
                for (t, &flag) in sinks.flags.iter().enumerate() {
                    self.bytes += prn.push(flag == 0)?;
                    if flag != 0 {
                        continue;
                    }
                    for idx in t * self.k..(t + 1) * self.k {
                        self.bps.write_all(&bps[idx].to_le_bytes())?;
                        self.bps
                            .write_all(&bpm[idx].to_u64().to_le_bytes()[..M::BYTES])?;
                    }
                    bps_written += self.k;
                }
            }
        }
        self.sink.write_all(&sinks.buf)?;
        self.bytes += (q.len() * QR_RECORD
            + bps_written * record_bytes::<M>()
            + sinks.buf.len()) as u64;
        sinks.buf.clear();
        sinks.flags.clear();
        self.entries += q.len() as u64;
        Ok(())
    }

    /// Finish all streams — flush, make durable, and (for staged
    /// writers) publish under the canonical keys; returns (subset
    /// entries, bytes written). `entries` counts every appended rank,
    /// present or pruned — the shard covers its full colex range either
    /// way. Durability errors propagate: the level must not commit over
    /// shard data the backend could not persist, and a staged stream is
    /// only published after its bytes are durable. (A crash between the
    /// finishes can leave a mix of published and unpublished streams —
    /// harmless, because the done marker that vouches for the shard is
    /// only written after all succeed, and the next attempt republishes
    /// identical bytes.)
    pub fn finish(mut self) -> Result<(u64, u64)> {
        if let Some(prn) = &mut self.prn {
            if prn.fill > 0 {
                self.bytes += prn.flush_block()?;
            }
        }
        self.bps.finish()?;
        self.qr.finish()?;
        self.sink.finish()?;
        if let Some(prn) = self.prn {
            prn.stream.finish()?;
        }
        Ok((self.entries, self.bytes))
    }
}

/// A direct-mapped window cache over one fixed-record-size shard stream
/// (the read half of the format; each worker opens its own, so no
/// cross-thread sharing). Each window miss is one positioned read —
/// a `pread` on POSIX, a ranged GET on an object store.
struct WindowedRecords {
    src: RefCell<Box<dyn RandomRead>>,
    cache: RefCell<WindowCache>,
    /// `root/key`, for error messages.
    name: String,
    record: usize,
    entries: usize,
    slots: usize,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

struct WindowCache {
    tags: Vec<i64>,
    data: Vec<u8>,
}

impl WindowedRecords {
    /// Open + fully validate one shard stream: v1 header fields *and*
    /// the exact byte length implied by `entries` (a truncated or
    /// corrupt shard fails here, by name, before any rank is served).
    #[allow(clippy::too_many_arguments)]
    fn open(
        store: &SharedBackend,
        key: &str,
        width_bytes: usize,
        k: usize,
        kind: u8,
        record: usize,
        entries: usize,
        slots_budget: usize,
    ) -> Result<WindowedRecords> {
        let name = format!("{}/{key}", store.root());
        let mut src = store.open_random(key)?;
        let mut header = [0u8; HEADER];
        src.read_exact_at(0, &mut header)
            .with_context(|| format!("reading header of {name}"))?;
        decode_header(&header, width_bytes, k, kind, &name)?;
        let expect_len = (HEADER + entries * record) as u64;
        let actual = src.len();
        if actual != expect_len {
            bail!(
                "{name}: shard file is {actual} bytes but {expect_len} were expected \
                 ({entries} records of {record} bytes + {HEADER}-byte header) — \
                 the file is truncated or from a different run"
            );
        }
        let slots = slots_budget.min(entries.div_ceil(WINDOW)).max(1);
        Ok(WindowedRecords {
            src: RefCell::new(src),
            cache: RefCell::new(WindowCache {
                tags: vec![-1; slots],
                data: vec![0; slots * WINDOW * record],
            }),
            name,
            record,
            entries,
            slots,
            hits: Cell::new(0),
            misses: Cell::new(0),
        })
    }

    fn resident_bytes(&self) -> usize {
        self.slots * WINDOW * self.record + self.slots * 8
    }

    /// Copy record `idx` into `out[..record]` through the window cache.
    #[inline]
    fn read_into(&self, idx: usize, out: &mut [u8]) {
        debug_assert!(idx < self.entries, "{}: record {idx} out of range", self.name);
        let record = self.record;
        let window = idx / WINDOW;
        let within = idx % WINDOW;
        let slot = window % self.slots;
        let mut cache = self.cache.borrow_mut();
        if cache.tags[slot] != window as i64 {
            self.misses.set(self.misses.get() + 1);
            let start = window * WINDOW;
            let len = WINDOW.min(self.entries - start);
            let base = slot * WINDOW * record;
            // I/O failures after open-time validation are unrecoverable
            // mid-sweep (the hot read path returns values, not Results);
            // name the file so the abort is actionable.
            self.src
                .borrow_mut()
                .read_exact_at(
                    (HEADER + start * record) as u64,
                    &mut cache.data[base..base + len * record],
                )
                .unwrap_or_else(|e| {
                    panic!("{}: read of window {window} failed: {e:#}", self.name)
                });
            cache.tags[slot] = window as i64;
        } else {
            self.hits.set(self.hits.get() + 1);
        }
        let off = slot * WINDOW * record + within * record;
        out[..record].copy_from_slice(&cache.data[off..off + record]);
    }
}

/// Read access to one *committed* level across all of its shard files.
///
/// Every worker opens its own reader (own file handles + caches), so the
/// shard-parallel sweep needs no cross-thread synchronisation; colex
/// locality of the drop-one ranks keeps the per-shard window caches hot
/// exactly as in the unsharded spill path.
pub struct ShardedLevelReader<M: VarMask> {
    pub k: usize,
    spec: ShardSpec,
    /// `.qr` reader per shard (`None` for empty shards).
    qr: Vec<Option<WindowedRecords>>,
    /// `.bps` reader per shard (`None` for empty shards and at level 0,
    /// which has no best-parent records). Prune-format shards hold slim
    /// streams: one row of `k` records per *surviving* subset.
    bps: Vec<Option<WindowedRecords>>,
    /// `.prn` presence sidecar per shard (`None` for dense-format runs,
    /// level 0 and empty shards).
    prn: Vec<Option<WindowedRecords>>,
    /// One decoded `.prn` block, cached — colex locality of the
    /// drop-one ranks makes consecutive `bps_at` calls hit the same
    /// block almost every time, so the 520-byte record is not re-copied
    /// and re-decoded per read.
    prn_cache: RefCell<PrnBlockCache>,
    _width: PhantomData<M>,
}

struct PrnBlockCache {
    /// `(shard, block)` tag; `block < 0` = empty cache.
    shard: usize,
    block: i64,
    prefix: u64,
    bits: [u8; PRN_RECORD - 8],
}

impl<M: VarMask> ShardedLevelReader<M> {
    pub fn open(run: &ShardRun, binom: &BinomTable, k: usize) -> Result<ShardedLevelReader<M>> {
        debug_assert_eq!(run.mask_bytes, M::BYTES);
        let spec = run.spec(binom, k);
        let slots = slot_cap(spec.shards);
        let prune_format = run.prune.is_some() && k >= 1;
        let mut qr = Vec::with_capacity(spec.shards);
        let mut bps = Vec::with_capacity(spec.shards);
        let mut prn = Vec::with_capacity(spec.shards);
        for s in 0..spec.shards {
            let entries = spec.entries(s) as usize;
            if entries == 0 {
                qr.push(None);
                bps.push(None);
                prn.push(None);
                continue;
            }
            qr.push(Some(WindowedRecords::open(
                &run.store,
                &run.shard_key(k, s, "qr"),
                M::BYTES,
                k,
                KIND_QR,
                QR_RECORD,
                entries,
                slots,
            )?));
            // a prune-format shard's .bps holds rows for survivors only;
            // the survivor count comes from the last .prn block (its
            // before-the-block prefix plus its own popcount)
            let bps_entries = if prune_format {
                let blocks = entries.div_ceil(PRN_BLOCK);
                let reader = WindowedRecords::open(
                    &run.store,
                    &run.shard_key(k, s, "prn"),
                    M::BYTES,
                    k,
                    KIND_PRN,
                    PRN_RECORD,
                    blocks,
                    slots,
                )?;
                let mut last = [0u8; PRN_RECORD];
                reader.read_into(blocks - 1, &mut last);
                let prefix = u64::from_le_bytes(last[..8].try_into().unwrap());
                let tail: u64 = last[8..].iter().map(|b| b.count_ones() as u64).sum();
                prn.push(Some(reader));
                (prefix + tail) as usize * k
            } else {
                prn.push(None);
                entries * k
            };
            bps.push(if k == 0 {
                None
            } else {
                Some(WindowedRecords::open(
                    &run.store,
                    &run.shard_key(k, s, "bps"),
                    M::BYTES,
                    k,
                    KIND_BPS,
                    record_bytes::<M>(),
                    bps_entries,
                    slots,
                )?)
            });
        }
        Ok(ShardedLevelReader {
            k,
            spec,
            qr,
            bps,
            prn,
            prn_cache: RefCell::new(PrnBlockCache {
                shard: 0,
                block: -1,
                prefix: 0,
                bits: [0u8; PRN_RECORD - 8],
            }),
            _width: PhantomData,
        })
    }

    /// `(log Q, log R)` of the subset at global rank `t` — one windowed
    /// record read (the hot transition loop needs both per drop-rank).
    #[inline]
    pub fn qr_at(&self, t: usize) -> (f64, f64) {
        let (s, local) = self.spec.locate(t as u64);
        let mut buf = [0u8; QR_RECORD];
        self.qr[s]
            .as_ref()
            .expect("rank routed to an empty shard")
            .read_into(local as usize, &mut buf);
        (
            f64::from_le_bytes(buf[..8].try_into().unwrap()),
            f64::from_le_bytes(buf[8..].try_into().unwrap()),
        )
    }

    /// `log Q` of the subset at global rank `t`.
    #[inline]
    pub fn q_at(&self, t: usize) -> f64 {
        self.qr_at(t).0
    }

    /// `log R` of the subset at global rank `t`.
    #[inline]
    pub fn r_at(&self, t: usize) -> f64 {
        self.qr_at(t).1
    }

    /// Best family score + argmax parent mask at flat index `t*k + pos`.
    /// In a prune-format level, a pruned subset's row was never written;
    /// its slots read back as the `(−∞, ∅)` the sweep stored in RAM, so
    /// the caller-side recurrences are untouched by the slim layout.
    #[inline]
    pub fn bps_at(&self, idx: usize) -> (f64, M) {
        let t = idx / self.k;
        let pos = idx % self.k;
        let (s, local) = self.spec.locate(t as u64);
        let row = match &self.prn[s] {
            None => local as usize,
            Some(prn) => match self.survivor_row(prn, s, local as usize) {
                Some(row) => row,
                None => return (f64::NEG_INFINITY, M::ZERO),
            },
        };
        let mut buf = [0u8; 16];
        let record = record_bytes::<M>();
        self.bps[s]
            .as_ref()
            .expect("bps read at level 0 or empty shard")
            .read_into(row * self.k + pos, &mut buf[..record]);
        let score = f64::from_le_bytes(buf[..8].try_into().unwrap());
        let mut raw = [0u8; 8];
        raw[..M::BYTES].copy_from_slice(&buf[8..8 + M::BYTES]);
        (score, M::from_u64(u64::from_le_bytes(raw)))
    }

    /// Row of shard-local rank `local` in the shard's slim `.bps`
    /// stream, or `None` if the rank was pruned: the covering `.prn`
    /// block's survivor prefix plus the popcount of presence bits below
    /// the rank.
    fn survivor_row(&self, prn: &WindowedRecords, s: usize, local: usize) -> Option<usize> {
        let block = local / PRN_BLOCK;
        let within = local % PRN_BLOCK;
        let mut cache = self.prn_cache.borrow_mut();
        if cache.shard != s || cache.block != block as i64 {
            let mut buf = [0u8; PRN_RECORD];
            prn.read_into(block, &mut buf);
            cache.shard = s;
            cache.block = block as i64;
            cache.prefix = u64::from_le_bytes(buf[..8].try_into().unwrap());
            cache.bits.copy_from_slice(&buf[8..]);
        }
        if cache.bits[within / 8] & (1 << (within % 8)) == 0 {
            return None;
        }
        let mut row = cache.prefix;
        for b in &cache.bits[..within / 8] {
            row += b.count_ones() as u64;
        }
        row += (cache.bits[within / 8] & ((1u8 << (within % 8)) - 1)).count_ones() as u64;
        Some(row as usize)
    }

    /// Resident bytes of this reader's window caches (memory accounting).
    pub fn resident_bytes(&self) -> usize {
        let sum = |files: &[Option<WindowedRecords>]| -> usize {
            files
                .iter()
                .flatten()
                .map(WindowedRecords::resident_bytes)
                .sum()
        };
        sum(&self.qr) + sum(&self.bps) + sum(&self.prn)
    }
}

/// Read one record of a shard stream without a cache (used a handful of
/// times per run: reconstruction + the final score).
#[allow(clippy::too_many_arguments)]
fn read_one_record(
    store: &SharedBackend,
    key: &str,
    width_bytes: usize,
    k: usize,
    kind: u8,
    record: usize,
    idx: u64,
    out: &mut [u8],
) -> Result<()> {
    let name = format!("{}/{key}", store.root());
    let mut src = store.open_random(key)?;
    let mut header = [0u8; HEADER];
    src.read_exact_at(0, &mut header)
        .with_context(|| format!("reading header of {name}"))?;
    decode_header(&header, width_bytes, k, kind, &name)?;
    src.read_exact_at(HEADER as u64 + idx * record as u64, &mut out[..record])
        .with_context(|| format!("reading record {idx} of {name}"))?;
    Ok(())
}

/// `log R(V)` of a fully committed run: the single `.qr` record of
/// level `p`.
pub fn final_score<M: VarMask>(run: &ShardRun) -> Result<f64> {
    let spec = ShardSpec::new(1, run.shards);
    let (s, local) = spec.locate(0);
    let mut buf = [0u8; QR_RECORD];
    read_one_record(
        &run.store,
        &run.shard_key(run.p, s, "qr"),
        M::BYTES,
        run.p,
        KIND_QR,
        QR_RECORD,
        local,
        &mut buf,
    )?;
    Ok(f64::from_le_bytes(buf[8..].try_into().unwrap()))
}

/// Disk-backed reconstruction (§3 step 4–5): walk the sinks from the
/// full set down to ∅ reading **one** `.sink` record per level, instead
/// of indexing `(1+mask)·2^p` bytes of in-RAM tables — this is what
/// frees the sharded path from the sink-table RAM cap.
pub fn reconstruct_from_disk<M: VarMask>(
    run: &ShardRun,
    binom: &BinomTable,
) -> Result<(Dag, Vec<usize>)> {
    let p = run.p;
    let mut mask = M::low_bits(p);
    let mut parents = vec![0u64; p];
    let mut order_rev = Vec::with_capacity(p);
    let record = sink_record_bytes::<M>();
    let mut buf = [0u8; 9];
    for k in (1..=p).rev() {
        let rank = colex_rank(binom, mask);
        let (s, local) = run.spec(binom, k).locate(rank);
        // prune-format levels store slim .sink streams: route the
        // shard-local rank through the .prn presence sidecar. The chain
        // subsets of the optimal order always survive the bound check
        // (the bounds are admissible), so an absent record here means
        // the directory is corrupt, not that pruning was too eager.
        let sink_idx = if run.prune.is_some() {
            let mut prn = [0u8; PRN_RECORD];
            read_one_record(
                &run.store,
                &run.shard_key(k, s, "prn"),
                M::BYTES,
                k,
                KIND_PRN,
                PRN_RECORD,
                local / PRN_BLOCK as u64,
                &mut prn,
            )?;
            let within = (local % PRN_BLOCK as u64) as usize;
            let bits = &prn[8..];
            if bits[within / 8] & (1 << (within % 8)) == 0 {
                bail!(
                    "{}: the optimal order's rank-{rank} subset was pruned \
                     from level {k} — the run directory is corrupt or its \
                     bounds were not admissible",
                    run.shard_file(k, s, "prn").display()
                );
            }
            let mut row = u64::from_le_bytes(prn[..8].try_into().unwrap());
            for b in &bits[..within / 8] {
                row += b.count_ones() as u64;
            }
            row += (bits[within / 8] & ((1u8 << (within % 8)) - 1)).count_ones() as u64;
            row
        } else {
            local
        };
        read_one_record(
            &run.store,
            &run.shard_key(k, s, "sink"),
            M::BYTES,
            k,
            KIND_SINK,
            record,
            sink_idx,
            &mut buf,
        )?;
        let x = buf[0] as usize;
        let mut raw = [0u8; 8];
        raw[..M::BYTES].copy_from_slice(&buf[1..1 + M::BYTES]);
        let pmask = u64::from_le_bytes(raw);
        // range-check before mask ops: a rotted sink byte must hit the
        // named corruption error below, not a bit-shift/index panic
        if x >= p || !mask.contains(x) {
            bail!(
                "{}: recorded sink X{x} is not in the rank-{rank} subset — \
                 the run directory is corrupt or from a different dataset",
                run.shard_file(k, s, "sink").display()
            );
        }
        if pmask & !mask.without(x).to_u64() != 0 {
            bail!(
                "{}: recorded parent set escapes its subset (rank {rank})",
                run.shard_file(k, s, "sink").display()
            );
        }
        parents[x] = pmask;
        order_rev.push(x);
        mask = mask.without(x);
    }
    order_rev.reverse();
    Ok((Dag::from_parents(parents), order_rev))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bnsl_shard_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn spec_partitions_every_rank_exactly_once() {
        for size in [1u64, 2, 5, 16, 100, 184_756] {
            for shards in [1usize, 2, 4, 8, 64] {
                let spec = ShardSpec::new(size, shards);
                let mut covered = 0u64;
                for s in 0..shards {
                    let (lo, hi) = spec.bounds(s);
                    assert_eq!(lo, covered.min(size), "contiguous");
                    assert!(hi >= lo);
                    covered = hi;
                    for rank in lo..hi.min(lo + 50) {
                        let (s2, local) = spec.locate(rank);
                        assert_eq!(s2, s, "rank {rank} of {size}/{shards}");
                        assert_eq!(local, rank - lo);
                    }
                }
                assert_eq!(covered, size, "all ranks covered");
            }
        }
    }

    #[test]
    fn spec_top_bits_for_power_of_two_sizes() {
        // size 2^10, 4 shards: shard index == top 2 bits of the rank.
        let spec = ShardSpec::new(1024, 4);
        for rank in (0..1024u64).step_by(17) {
            assert_eq!(spec.locate(rank).0 as u64, rank >> 8);
        }
    }

    #[test]
    fn manifest_roundtrip_and_commit() {
        let dir = tmpdir("manifest");
        let opts = ShardOptions {
            shards: 4,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut run =
            ShardRun::open_or_create(&opts, 12, 200, 4, "Jeffreys", "00ff00ff00ff00ff", None).unwrap();
        assert_eq!(run.completed, None);
        run.commit_level(0).unwrap();
        run.commit_level(1).unwrap();
        let back = ShardRun::open(&dir).unwrap();
        assert_eq!(back.completed, Some(1));
        assert_eq!(back.p, 12);
        assert_eq!(back.shards, 4);
        assert_eq!(back.score, "Jeffreys");
        // resume path: same identity is accepted, shards come from the manifest
        let resumed = ShardRun::open_or_create(
            &ShardOptions {
                shards: 0,
                dir: dir.clone(),
                ..Default::default()
            },
            12,
            200,
            4,
            "Jeffreys",
            "00ff00ff00ff00ff",
            None,
        )
        .unwrap();
        assert_eq!(resumed.shards, 4);
        assert_eq!(resumed.completed, Some(1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_v2_records_hosts_and_reads_v1_without_them() {
        let dir = tmpdir("hosts");
        let opts = ShardOptions {
            shards: 2,
            hosts: 3,
            dir: dir.clone(),
            ..Default::default()
        };
        ShardRun::open_or_create(&opts, 9, 50, 4, "Bic", "abcd", None).unwrap();
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(text.contains("\"format\": 3"), "{text}");
        assert!(text.contains("\"hosts\": 3"), "{text}");
        assert_eq!(ShardRun::open(&dir).unwrap().hosts, 3);
        // a v1 manifest (no hosts field) still opens, defaulting to 1
        let v1 = text
            .replace("\"format\": 3", "\"format\": 1")
            .lines()
            .filter(|l| !l.contains("\"hosts\""))
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(dir.join("manifest.json"), v1).unwrap();
        let back = ShardRun::open(&dir).unwrap();
        assert_eq!(back.hosts, 1);
        // ...and a future format is rejected by version range
        let v9 = text.replace("\"format\": 3", "\"format\": 9");
        std::fs::write(dir.join("manifest.json"), v9).unwrap();
        let err = ShardRun::open(&dir).unwrap_err().to_string();
        assert!(err.contains("format 9"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn staged_writer_publishes_only_at_finish() {
        let dir = tmpdir("staged");
        let opts = ShardOptions {
            shards: 1,
            dir: dir.clone(),
            ..Default::default()
        };
        let run = ShardRun::open_or_create(&opts, 8, 10, 4, "Jeffreys", "ff", None).unwrap();
        let k = 2;
        let mut w = ShardWriterSet::<u32>::create_staged(&run, k, 0, "host-0001-42").unwrap();
        let mut sinks = SinkBuf::default();
        sinks.put(0u32, 1, 0);
        w.append(&[1.0], &[2.0], &[0.5, 0.25], &[3u32, 5], &mut sinks)
            .unwrap();
        // nothing canonical exists while the writer is staging
        for ext in ["bps", "qr", "sink"] {
            assert!(!run.shard_file(k, 0, ext).exists(), "{ext} published early");
        }
        let (entries, bytes) = w.finish().unwrap();
        assert_eq!(entries, 1);
        assert!(bytes > 0);
        // finish renamed every stream into place and left no staged strays
        for ext in ["bps", "qr", "sink"] {
            let canon = run.shard_file(k, 0, ext);
            assert!(canon.exists(), "{ext} missing after publish");
            let mut staged = canon.as_os_str().to_os_string();
            staged.push(".host-0001-42");
            assert!(!PathBuf::from(staged).exists(), "{ext} stray remains");
        }
        // and the published .qr stream reads back like a direct write
        let bytes = std::fs::read(run.shard_file(k, 0, "qr")).unwrap();
        assert_eq!(bytes.len(), HEADER + QR_RECORD);
        assert_eq!(
            f64::from_le_bytes(bytes[HEADER..HEADER + 8].try_into().unwrap()),
            1.0
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn commit_level_rejects_double_and_out_of_order_commits() {
        let dir = tmpdir("commit_order");
        let opts = ShardOptions {
            shards: 1,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut run = ShardRun::open_or_create(&opts, 6, 10, 4, "Bic", "11", None).unwrap();
        // skipping ahead is rejected
        let err = run.commit_level(1).unwrap_err().to_string();
        assert!(err.contains("out of order"), "{err}");
        run.commit_level(0).unwrap();
        run.commit_level(1).unwrap();
        // double commit is rejected by name
        let err = run.commit_level(1).unwrap_err().to_string();
        assert!(err.contains("already committed"), "{err}");
        assert_eq!(run.completed, Some(1), "failed commit left state intact");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fd_budget_prices_cluster_margin() {
        assert_eq!(fd_budget(2, 4, false), 2 * 16 + 32);
        assert_eq!(
            fd_budget(2, 4, true),
            fd_budget(2, 4, false) + CLUSTER_FD_MARGIN
        );
    }

    #[test]
    fn manifest_rejects_identity_mismatches_by_name() {
        let dir = tmpdir("mismatch");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        ShardRun::open_or_create(&opts, 10, 100, 4, "Bic", "aaaa", None).unwrap();
        let err = ShardRun::open_or_create(&opts, 11, 100, 4, "Bic", "aaaa", None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("p"), "{err}");
        let err = ShardRun::open_or_create(&opts, 10, 100, 4, "Bic", "bbbb", None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fingerprint"), "{err}");
        let err = ShardRun::open_or_create(
            &ShardOptions {
                shards: 8,
                dir: dir.clone(),
                ..Default::default()
            },
            10,
            100,
            4,
            "Bic",
            "aaaa",
            None,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("shards"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_non_power_of_two_shards() {
        let dir = tmpdir("pow2");
        let err = ShardRun::open_or_create(
            &ShardOptions {
                shards: 3,
                dir: dir.clone(),
                ..Default::default()
            },
            8,
            50,
            4,
            "Jeffreys",
            "cc",
            None,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("power of two"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn writer_reader_roundtrip_across_shards() {
        let dir = tmpdir("roundtrip");
        let p = 9;
        let k = 4;
        let binom = BinomTable::new(p);
        let opts = ShardOptions {
            shards: 4,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut run = ShardRun::open_or_create(&opts, p, 10, 4, "Jeffreys", "ee", None).unwrap();
        for lvl in 0..k {
            run.commit_level(lvl).ok();
        }
        let spec = run.spec(&binom, k);
        let size = spec.size as usize;
        // synthesise a known level: q = rank, r = -rank, bps = rank*k+j,
        // bpm = j-th drop mask stand-in (rank+j as mask bits)
        for s in 0..spec.shards {
            let (lo, hi) = spec.bounds(s);
            if lo >= hi {
                continue;
            }
            let mut w = ShardWriterSet::<u32>::create(&run, k, s).unwrap();
            let mut sinks = SinkBuf::default();
            for t in lo..hi {
                let q = [t as f64];
                let r = [-(t as f64)];
                let bps: Vec<f64> = (0..k).map(|j| (t as usize * k + j) as f64).collect();
                let bpm: Vec<u32> = (0..k).map(|j| (t as u32) ^ (j as u32)).collect();
                sinks.put(0u32, (t % 7) as u8, t as u32);
                w.append(&q, &r, &bps, &bpm, &mut sinks).unwrap();
            }
            let (entries, bytes) = w.finish().unwrap();
            assert_eq!(entries, hi - lo);
            assert!(bytes > 0);
        }
        run.commit_level(k).unwrap();
        let reader = ShardedLevelReader::<u32>::open(&run, &binom, k).unwrap();
        for t in (0..size).step_by(3) {
            assert_eq!(reader.q_at(t), t as f64);
            assert_eq!(reader.r_at(t), -(t as f64));
            for j in 0..k {
                let (s, m) = reader.bps_at(t * k + j);
                assert_eq!(s, (t * k + j) as f64);
                assert_eq!(m, (t as u32) ^ (j as u32));
            }
        }
        assert!(reader.resident_bytes() > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_v3_roundtrips_the_prune_stamp() {
        let dir = tmpdir("prune_stamp");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let stamp = PruneStamp {
            incumbent_bits: (-12.5f64).to_bits(),
            ub_hash: 0xfeed_beef_dead_cafe,
        };
        ShardRun::open_or_create(&opts, 7, 10, 4, "Bic", "ab12", Some(stamp)).unwrap();
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(text.contains("feedbeefdeadcafe"), "{text}");
        assert_eq!(ShardRun::open(&dir).unwrap().prune, Some(stamp));
        // on resume the manifest's recorded stamp wins over the caller's
        let resumed =
            ShardRun::open_or_create(&opts, 7, 10, 4, "Bic", "ab12", None).unwrap();
        assert_eq!(resumed.prune, Some(stamp), "manifest stamp survives resume");
        // a manifest without the fields is a plain dense-format run…
        let dense = text
            .lines()
            .filter(|l| !l.contains("prune_"))
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(dir.join("manifest.json"), &dense).unwrap();
        assert_eq!(ShardRun::open(&dir).unwrap().prune, None);
        // …and a half-written stamp is rejected as corrupt
        let half = text
            .lines()
            .filter(|l| !l.contains("prune_ub_hash"))
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(dir.join("manifest.json"), &half).unwrap();
        let err = ShardRun::open(&dir).unwrap_err().to_string();
        assert!(err.contains("prune_incumbent"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pruned_ranks_vanish_from_bps_but_stay_dense_in_qr() {
        let dir = tmpdir("prune_slim");
        // C(16,8) = 12870: each of 2 shards spans more than one 4096-rank
        // .prn block, so the survivor-prefix arithmetic crosses blocks.
        let p = 16;
        let k = 8;
        let binom = BinomTable::new(p);
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let stamp = PruneStamp {
            incumbent_bits: 1,
            ub_hash: 2,
        };
        let mut run =
            ShardRun::open_or_create(&opts, p, 10, 4, "Bic", "cc", Some(stamp)).unwrap();
        for lvl in 0..k {
            run.commit_level(lvl).ok();
        }
        let spec = run.spec(&binom, k);
        let dropped = |t: u64| t % 3 == 1;
        for s in 0..spec.shards {
            let (lo, hi) = spec.bounds(s);
            let mut w = ShardWriterSet::<u32>::create(&run, k, s).unwrap();
            let mut sinks = SinkBuf::default();
            for t in lo..hi {
                if dropped(t) {
                    sinks.put_pruned(t as u32);
                } else {
                    sinks.put(t as u32, (t % 5) as u8, t as u32);
                }
                let bps: Vec<f64> = (0..k).map(|j| (t as usize * k + j) as f64).collect();
                let bpm: Vec<u32> = (0..k).map(|j| (t as u32) ^ (j as u32)).collect();
                w.append(&[t as f64], &[-(t as f64)], &bps, &bpm, &mut sinks)
                    .unwrap();
            }
            let (entries, _) = w.finish().unwrap();
            assert_eq!(entries, hi - lo, "entries count totals, not survivors");
            assert!(
                run.shard_file(k, s, "prn").exists(),
                "prune-format shards always carry a presence sidecar"
            );
        }
        run.commit_level(k).unwrap();
        let reader = ShardedLevelReader::<u32>::open(&run, &binom, k).unwrap();
        for t in (0..spec.size as usize).step_by(7) {
            // q and r stay dense — every rank reads back
            assert_eq!(reader.q_at(t), t as f64);
            assert_eq!(reader.r_at(t), -(t as f64));
            for j in 0..k {
                let (sc, m) = reader.bps_at(t * k + j);
                if dropped(t as u64) {
                    assert_eq!(sc, f64::NEG_INFINITY, "rank {t} was pruned");
                    assert_eq!(m, 0);
                } else {
                    assert_eq!(sc, (t * k + j) as f64, "rank {t} survived");
                    assert_eq!(m, (t as u32) ^ (j as u32));
                }
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_format_without_drops_is_all_present() {
        let dir = tmpdir("prune_nodrop");
        let p = 8;
        let k = 3;
        let binom = BinomTable::new(p);
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let stamp = PruneStamp {
            incumbent_bits: 3,
            ub_hash: 4,
        };
        let run =
            ShardRun::open_or_create(&opts, p, 10, 4, "Bic", "dd", Some(stamp)).unwrap();
        let spec = run.spec(&binom, k);
        for s in 0..spec.shards {
            let (lo, hi) = spec.bounds(s);
            let mut w = ShardWriterSet::<u32>::create(&run, k, s).unwrap();
            let mut sinks = SinkBuf::default();
            for t in lo..hi {
                sinks.put(t as u32, 0, t as u32);
                let bps: Vec<f64> = (0..k).map(|j| (t as usize * k + j) as f64).collect();
                w.append(&[t as f64], &[0.0], &bps, &vec![0u32; k], &mut sinks)
                    .unwrap();
            }
            w.finish().unwrap();
            // the sidecar is written even when nothing was pruned, so the
            // level's on-disk format is uniform for readers and resumes
            assert!(run.shard_file(k, s, "prn").exists());
        }
        let reader = ShardedLevelReader::<u32>::open(&run, &binom, k).unwrap();
        for t in 0..spec.size as usize {
            for j in 0..k {
                assert_eq!(reader.bps_at(t * k + j).0, (t * k + j) as f64);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reader_names_corrupt_and_truncated_files() {
        let dir = tmpdir("corrupt");
        let p = 8;
        let k = 3;
        let binom = BinomTable::new(p);
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let run = ShardRun::open_or_create(&opts, p, 10, 4, "Jeffreys", "dd", None).unwrap();
        let spec = run.spec(&binom, k);
        for s in 0..spec.shards {
            let (lo, hi) = spec.bounds(s);
            let mut w = ShardWriterSet::<u32>::create(&run, k, s).unwrap();
            let mut sinks = SinkBuf::default();
            for t in lo..hi {
                sinks.put(0u32, 0, 0);
                w.append(
                    &[0.0],
                    &[0.0],
                    &vec![0.0; k],
                    &vec![0u32; k],
                    &mut sinks,
                )
                .unwrap();
            }
            w.finish().unwrap();
        }
        // flip a header byte of shard 1's bps file
        let victim = run.shard_file(k, 1, "bps");
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        let err = ShardedLevelReader::<u32>::open(&run, &binom, k)
            .unwrap_err()
            .to_string();
        assert!(
            err.contains(&victim.display().to_string()),
            "error names the corrupt file: {err}"
        );
        assert!(err.contains("magic"), "{err}");
        // restore the header but truncate the tail: length check fires
        bytes[0] ^= 0xFF;
        bytes.truncate(bytes.len() - 1);
        std::fs::write(&victim, &bytes).unwrap();
        let err = ShardedLevelReader::<u32>::open(&run, &binom, k)
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The object backend speaks the same key layout and byte formats:
    /// a run written through it is readable file-for-file, the staged
    /// copy-publish leaves no strays, and a POSIX `ShardRun::open` of
    /// the same root sees an identical manifest (keys mirror paths).
    #[test]
    fn object_backend_runs_mirror_the_posix_layout() {
        let dir = tmpdir("object_layout");
        let p = 9;
        let k = 3;
        let binom = BinomTable::new(p);
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            backend: BackendKind::Object,
            ..Default::default()
        };
        let mut run = ShardRun::open_or_create(&opts, p, 10, 4, "Jeffreys", "0b0b", None).unwrap();
        assert_eq!(run.store().kind(), BackendKind::Object);
        for lvl in 0..k {
            run.commit_level(lvl).ok();
        }
        let spec = run.spec(&binom, k);
        for s in 0..spec.shards {
            let (lo, hi) = spec.bounds(s);
            if lo >= hi {
                continue;
            }
            // staged, like a cluster host would write
            let mut w =
                ShardWriterSet::<u32>::create_staged(&run, k, s, "host-0000-1-0").unwrap();
            let mut sinks = SinkBuf::default();
            for t in lo..hi {
                sinks.put(0u32, (t % 5) as u8, t as u32);
                let bps: Vec<f64> = (0..k).map(|j| (t as usize * k + j) as f64).collect();
                let bpm: Vec<u32> = (0..k).map(|j| (t as u32) ^ (j as u32)).collect();
                w.append(&[t as f64], &[-(t as f64)], &bps, &bpm, &mut sinks)
                    .unwrap();
            }
            w.finish().unwrap();
        }
        run.commit_level(k).unwrap();
        let reader = ShardedLevelReader::<u32>::open(&run, &binom, k).unwrap();
        for t in (0..spec.size as usize).step_by(2) {
            assert_eq!(reader.q_at(t), t as f64);
            assert_eq!(reader.r_at(t), -(t as f64));
        }
        // the canonical files on disk are plain v1-format shard files…
        let bytes = std::fs::read(run.shard_file(k, 0, "qr")).unwrap();
        assert_eq!(&bytes[..8], b"BNSLSPIL");
        // …no staged strays survive the copy-publish…
        let strays: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".host-") || n.contains(".otmp."))
            .collect();
        assert!(strays.is_empty(), "{strays:?}");
        // …the manifest on disk records the binding in plain JSON…
        let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(manifest.contains("\"backend\": \"object\""), "{manifest}");
        // …and a POSIX *open* of the object-bound root is rejected with
        // the flag to use (mixed backends judge liveness differently)
        let err = ShardRun::open(&dir).unwrap_err().to_string();
        assert!(err.contains("bound"), "{err}");
        assert!(err.contains("--backend object"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Review-round regression: a joining host whose manifest existence
    /// probe *lags* (the object store's read-after-write window,
    /// injected via `stale_reads`) must not overwrite a committed run's
    /// manifest with a fresh `levels_complete = -1` — the initial
    /// manifest write is a conditional publish, and the lagged creator
    /// falls back to the ordinary validate-and-resume path.
    #[test]
    fn lagged_existence_probe_cannot_overwrite_a_committed_manifest() {
        use crate::coordinator::storage::{ObjectBackend, ObjectFaults};
        let dir = tmpdir("lagged_probe");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            backend: BackendKind::Object,
            ..Default::default()
        };
        let mut run = ShardRun::open_or_create(&opts, 8, 40, 4, "Jeffreys", "cafe", None).unwrap();
        run.commit_level(0).unwrap();
        // a second host joins through a store whose next TWO GETs lie:
        // the existence probe (sending it down the create path, where
        // the conditional publish loses) AND the first validate-resume
        // read — the entry path must ride out both, not die on either
        let object = ObjectBackend::with_faults(&dir, ObjectFaults::default());
        object
            .faults()
            .stale_reads
            .store(2, std::sync::atomic::Ordering::Relaxed);
        let store: SharedBackend = Arc::new(object);
        let joined =
            ShardRun::open_or_create_on(store, &opts, 8, 40, 4, "Jeffreys", "cafe", None).unwrap();
        assert_eq!(
            joined.completed,
            Some(0),
            "committed progress survived the lagged probes"
        );
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(
            text.contains("\"levels_complete\": 0"),
            "manifest not regressed: {text}"
        );
        // explicit resume intent (shards = 0) with a lagged existence
        // probe: re-probed within the grace window, not "nothing to
        // resume"
        let object = ObjectBackend::with_faults(&dir, ObjectFaults::default());
        object
            .faults()
            .stale_reads
            .store(1, std::sync::atomic::Ordering::Relaxed);
        let store: SharedBackend = Arc::new(object);
        let resumed = ShardRun::open_or_create_on(
            store,
            &ShardOptions {
                shards: 0,
                dir: dir.clone(),
                backend: BackendKind::Object,
                ..Default::default()
            },
            8,
            40,
            4,
            "Jeffreys",
            "cafe",
            None,
        )
        .unwrap();
        assert_eq!(resumed.completed, Some(0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn object_backend_resume_validates_identity_like_posix() {
        let dir = tmpdir("object_resume");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            backend: BackendKind::Object,
            ..Default::default()
        };
        ShardRun::open_or_create(&opts, 10, 100, 4, "Bic", "aaaa", None).unwrap();
        // resume with shards = 0 adopts the manifest geometry
        let resumed = ShardRun::open_or_create(
            &ShardOptions {
                shards: 0,
                dir: dir.clone(),
                backend: BackendKind::Object,
                ..Default::default()
            },
            10,
            100,
            4,
            "Bic",
            "aaaa",
            None,
        )
        .unwrap();
        assert_eq!(resumed.shards, 2);
        // identity mismatches are rejected by name, same as POSIX
        let err = ShardRun::open_or_create(&opts, 10, 100, 4, "Bic", "bbbb", None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fingerprint"), "{err}");
        // …and the backend itself is part of the run's identity: a
        // mismatched join is rejected with the flag to use (mixed
        // backends would judge liveness by different stamps)
        let err = ShardRun::open_or_create(
            &ShardOptions {
                shards: 0,
                dir: dir.clone(),
                backend: BackendKind::Posix,
                ..Default::default()
            },
            10,
            100,
            4,
            "Bic",
            "aaaa",
            None,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("--backend object"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_distinguishes_data_and_score() {
        let a = synth::binary(5, 40, 1);
        let b = synth::binary(5, 40, 2);
        let fa = run_fingerprint(&a, ScoreKind::Jeffreys);
        assert_eq!(fa, run_fingerprint(&a, ScoreKind::Jeffreys), "stable");
        assert_ne!(fa, run_fingerprint(&b, ScoreKind::Jeffreys), "data-sensitive");
        assert_ne!(
            fa,
            run_fingerprint(&a, ScoreKind::Bic),
            "score-sensitive"
        );
        assert_ne!(
            run_fingerprint(&a, ScoreKind::Bdeu { ess: 1.0 }),
            run_fingerprint(&a, ScoreKind::Bdeu { ess: 2.0 }),
            "hyperparameter-sensitive"
        );
        assert_eq!(fa.len(), 16, "16 hex chars");
    }

    #[test]
    fn reader_cache_is_bounded_by_file_size_and_shard_count() {
        // tiny shard: one window, not SLOTS of them
        assert!(reader_cache_bytes(10, 12, 1) <= WINDOW * 12 + 8);
        // huge shard, one shard: capped at SLOTS windows
        assert_eq!(
            reader_cache_bytes(100 * SLOTS * WINDOW, 12, 1),
            SLOTS * WINDOW * 12 + SLOTS * 8
        );
        // the slot budget divides across shards, so aggregate cache is
        // constant in the shard count
        let total_4: usize = (0..4).map(|_| reader_cache_bytes(usize::MAX / 256, 12, 4)).sum();
        assert_eq!(total_4, SLOTS * WINDOW * 12 + SLOTS * 8);
        // and never collapses to zero
        assert!(reader_cache_bytes(1, 16, 1024) >= WINDOW * 16);
    }
}
