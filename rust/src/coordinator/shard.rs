//! Sharded frontier files + run manifest — the external-memory
//! coordinator behind [`crate::solver::solve_sharded`].
//!
//! The paper's single-traversal DP keeps two adjacent subset levels in
//! RAM; the §5.3 spill pushes the dominant best-parent vectors of peak
//! levels to disk but leaves the `16·C(p,p/2)`-byte `q`/`r` frontier and
//! the `(1+mask)·2^p` sink tables resident, which caps the wide exact
//! path at `p = `[`crate::MAX_VARS_WIDE`]. This module removes both
//! residents, Malone-style (external-memory frontier breadth-first
//! search): every level is partitioned into [`ShardSpec::shards`]
//! equal colex-rank ranges — for power-of-two level sizes exactly the
//! **top `log2(shards)` bits of the colex rank** — and each shard streams
//! its third of the frontier (`.bps`, `.qr`, `.sink` files, one spill
//! writer per shard) through a fixed-size batch buffer. The next level
//! reads the previous one through per-worker window caches
//! ([`ShardedLevelReader`]), and reconstruction random-accesses the
//! per-level `.sink` files instead of a `2^p` in-RAM table, so peak RAM
//! is `O(shards · (batch + cache))` — per-shard frontier, not per-level.
//!
//! A `manifest.json` in the run directory records the run's identity
//! (`p`, shard count, mask width, score, dataset fingerprint) and the
//! highest *committed* level. The manifest is rewritten atomically
//! (write-temp-then-rename) after each level's shards all finish, which
//! makes a killed run resumable at the last completed level:
//! `--resume <dir>` revalidates the manifest and every surviving shard
//! header, then continues the sweep without recomputing finished levels.
//!
//! All files share the 16-byte v1 header of [`crate::coordinator::spill`]
//! (magic, version, mask width, level, record kind). The byte-level
//! specification — header layout, the three record kinds, the manifest
//! schema, and a worked hex example — lives in
//! [`docs/FORMATS.md`](https://github.com/paper-repo-growth/bnsl/blob/main/docs/FORMATS.md)
//! (in-tree: `docs/FORMATS.md`).

use super::spill::{
    decode_header, encode_header, record_bytes, HEADER, KIND_BPS, KIND_QR, KIND_SINK,
};
use crate::bitset::{colex_rank, BinomTable, VarMask};
use crate::bn::Dag;
use crate::data::Dataset;
use crate::score::ScoreKind;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::cell::{Cell, RefCell};
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};

// Cache geometry is shared with the §5.3 spill reader so the two
// direct-mapped window caches cannot drift apart.
pub(crate) use super::spill::{SLOTS, WINDOW};

/// Manifest format version written by this binary. Version 2 (ISSUE 3)
/// added the informational `hosts` field alongside the cluster claim
/// ledger ([`crate::coordinator::cluster`]); version-1 manifests are
/// still read (the field defaults to 1).
const MANIFEST_FORMAT: u64 = 2;
/// Oldest manifest format this reader still understands.
const MANIFEST_FORMAT_MIN: u64 = 1;

/// Bytes of one `.qr` record: little-endian `f64` `log Q` + `f64` `log R`.
pub(crate) const QR_RECORD: usize = 16;

/// Bytes of one `.sink` record at width `M`: sink-variable byte + mask.
#[inline]
pub(crate) const fn sink_record_bytes<M: VarMask>() -> usize {
    1 + M::BYTES
}

/// Cache-slot budget per open shard file: the fixed [`SLOTS`] total is
/// divided across the level's shards so a reader's aggregate cache does
/// not grow with the shard count.
pub(crate) fn slot_cap(shards: usize) -> usize {
    (SLOTS / shards).max(1)
}

/// Resident bytes of the window cache a reader opens over `entries`
/// records of `record` bytes in one of `shards` shard files (shared with
/// the memory planner so [`crate::coordinator::plan`] prices exactly
/// what the reader allocates).
pub(crate) fn reader_cache_bytes(entries: usize, record: usize, shards: usize) -> usize {
    let slots = slot_cap(shards).min(entries.div_ceil(WINDOW)).max(1);
    slots * WINDOW * record + slots * 8
}

/// Extra handle headroom a cluster host needs on top of the worker-pool
/// read/write handles: transient claim / done-marker / finish-marker /
/// manifest-poll opens ([`crate::coordinator::cluster`]). Small but real
/// — the ledger is touched from inside the level loop, so budgeting it
/// up front keeps the preflight honest.
pub(crate) const CLUSTER_FD_MARGIN: u64 = 16;

/// Per-host open-file budget of a sharded run: every worker holds `.qr` +
/// `.bps` read handles for all previous-level shards plus its own three
/// writer streams, plus a fixed process margin; cluster mode adds the
/// claim-ledger headroom. Shared between the solver preflights and
/// [`crate::coordinator::plan::sharded_plan`], so `bnsl info` prices
/// exactly what the drivers check.
pub fn fd_budget(workers: usize, shards: usize, cluster: bool) -> u64 {
    let base = workers as u64 * (2 * shards as u64 + 3) + 32;
    if cluster {
        base + CLUSTER_FD_MARGIN
    } else {
        base
    }
}

/// Soft `RLIMIT_NOFILE` via `/proc/self/limits` (`None` off Linux or if
/// unreadable) — the sharded driver preflights its per-worker handle
/// budget against this instead of dying mid-level on EMFILE.
pub(crate) fn fd_soft_limit() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/limits").ok()?;
    let line = text.lines().find(|l| l.starts_with("Max open files"))?;
    // "Max open files   <soft>   <hard>   files"
    line.split_whitespace().nth(3)?.parse().ok()
}

/// Tuning knobs for one sharded run (see [`crate::solver::solve_sharded`]).
#[derive(Clone, Debug)]
pub struct ShardOptions {
    /// Number of frontier shards per level. Must be a power of two
    /// (shards are keyed by the top bits of the colex rank); `0` means
    /// "take the count from the manifest" (resume).
    pub shards: usize,
    /// Worker threads draining the shard queue; `0` = one per shard,
    /// capped at the machine's available parallelism (each worker holds
    /// read handles for every previous-level shard, so more workers than
    /// cores only burns file descriptors).
    pub workers: usize,
    /// Subsets scored per engine batch within each shard.
    pub batch: usize,
    /// Run directory: manifest + per-level shard files.
    pub dir: PathBuf,
    /// Checkpoint hook: commit levels up to and including this one, then
    /// return [`crate::solver::ShardOutcome::Checkpointed`] instead of
    /// finishing. Drives the kill-and-resume tests and time-boxed solves.
    pub stop_after_level: Option<usize>,
    /// Keep every level's `.bps`/`.qr` files instead of pruning levels
    /// that are no longer needed for resume (debugging aid).
    pub keep_levels: bool,
    /// Declared cluster size (informational, recorded in the v2 manifest;
    /// 1 for single-host runs). The claim ledger is elastic — hosts may
    /// join or vanish — so this is *not* validated on resume.
    pub hosts: usize,
}

impl Default for ShardOptions {
    fn default() -> ShardOptions {
        ShardOptions {
            shards: 1,
            workers: 0,
            batch: 1024,
            dir: PathBuf::from("bnsl_shards"),
            stop_after_level: None,
            keep_levels: false,
            hosts: 1,
        }
    }
}

/// Partition of one level's `C(p,k)` colex ranks into equal contiguous
/// ranges. With a power-of-two level size the shard index is literally
/// the top `log2(shards)` bits of the rank; ragged sizes round the range
/// width up, leaving trailing shards short or empty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Level size `C(p,k)`.
    pub size: u64,
    /// Shard count (power of two).
    pub shards: usize,
    /// Ranks per shard: `ceil(size / shards)`.
    pub width: u64,
}

impl ShardSpec {
    pub fn new(size: u64, shards: usize) -> ShardSpec {
        assert!(shards >= 1 && shards.is_power_of_two());
        ShardSpec {
            size,
            shards,
            width: size.div_ceil(shards as u64).max(1),
        }
    }

    /// Global rank range `[lo, hi)` of shard `s` (empty when `lo >= hi`).
    pub fn bounds(&self, s: usize) -> (u64, u64) {
        let lo = (s as u64 * self.width).min(self.size);
        let hi = ((s as u64 + 1) * self.width).min(self.size);
        (lo, hi)
    }

    /// Entries in shard `s`.
    pub fn entries(&self, s: usize) -> u64 {
        let (lo, hi) = self.bounds(s);
        hi - lo
    }

    /// Shard + shard-local offset of a global rank.
    #[inline]
    pub fn locate(&self, rank: u64) -> (usize, u64) {
        debug_assert!(rank < self.size);
        ((rank / self.width) as usize, rank % self.width)
    }
}

/// Stable identity of (dataset, score): resuming against different data
/// or a different scoring function is rejected up front instead of
/// producing a silently wrong network. FNV-1a over the dataset shape,
/// arities, raw column bytes and the score's debug form.
pub fn run_fingerprint(data: &Dataset, kind: ScoreKind) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(&(data.p() as u64).to_le_bytes());
    eat(&(data.n() as u64).to_le_bytes());
    eat(data.arities());
    for v in 0..data.p() {
        eat(data.column(v));
    }
    eat(format!("{kind:?}").as_bytes());
    format!("{h:016x}")
}

/// One sharded run rooted at a directory: identity + committed progress.
///
/// The manifest is the durability boundary. A level exists iff
/// `completed >= Some(k)`; files of uncommitted levels are ignored (and
/// overwritten) by the next attempt.
#[derive(Clone, Debug)]
pub struct ShardRun {
    dir: PathBuf,
    pub p: usize,
    pub n: usize,
    pub shards: usize,
    pub mask_bytes: usize,
    pub score: String,
    pub fingerprint: String,
    /// Declared cluster size when the run was created (informational;
    /// 1 for single-host runs and for v1 manifests).
    pub hosts: usize,
    /// Highest committed level (`None` before level 0 commits).
    pub completed: Option<usize>,
}

impl ShardRun {
    /// Start a fresh run, or resume the one already rooted at
    /// `options.dir`. A fresh run requires `options.shards >= 1`; a
    /// resume (`options.shards == 0` or a matching explicit count)
    /// revalidates `p`, mask width, score and dataset fingerprint
    /// against the manifest and rejects mismatches by name.
    pub fn open_or_create(
        options: &ShardOptions,
        p: usize,
        n: usize,
        mask_bytes: usize,
        score: &str,
        fingerprint: &str,
    ) -> Result<ShardRun> {
        let manifest = options.dir.join("manifest.json");
        if manifest.exists() {
            let run = ShardRun::open(&options.dir)?;
            let reject = |field: &str, manifest_has: &str, caller_has: &str| -> anyhow::Error {
                anyhow::anyhow!(
                    "{}: cannot resume — manifest records {field} = {manifest_has} \
                     but this invocation has {field} = {caller_has}; use a fresh \
                     --shard-dir for a different run",
                    manifest.display()
                )
            };
            if run.p != p {
                return Err(reject("p", &run.p.to_string(), &p.to_string()));
            }
            if run.mask_bytes != mask_bytes {
                return Err(reject(
                    "mask_bytes",
                    &run.mask_bytes.to_string(),
                    &mask_bytes.to_string(),
                ));
            }
            if run.score != score {
                return Err(reject("score", &run.score, score));
            }
            if run.fingerprint != fingerprint {
                return Err(reject("data fingerprint", &run.fingerprint, fingerprint));
            }
            if options.shards != 0 && options.shards != run.shards {
                return Err(reject(
                    "shards",
                    &run.shards.to_string(),
                    &options.shards.to_string(),
                ));
            }
            return Ok(run);
        }
        if options.shards == 0 {
            bail!(
                "{}: nothing to resume (no manifest.json); start a run with --shards N",
                options.dir.display()
            );
        }
        if !options.shards.is_power_of_two() {
            bail!(
                "--shards {} is not a power of two; shards are keyed by the \
                 top bits of the colex rank (try {} or {})",
                options.shards,
                options.shards.next_power_of_two() >> 1,
                options.shards.next_power_of_two()
            );
        }
        std::fs::create_dir_all(&options.dir)
            .with_context(|| format!("creating shard dir {}", options.dir.display()))?;
        let run = ShardRun {
            dir: options.dir.clone(),
            p,
            n,
            shards: options.shards,
            mask_bytes,
            score: score.to_string(),
            fingerprint: fingerprint.to_string(),
            hosts: options.hosts.max(1),
            completed: None,
        };
        run.write_manifest()?;
        Ok(run)
    }

    /// The run directory (manifest, shard files, and — in cluster mode —
    /// the claim ledger).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load an existing run's manifest (resume entry point).
    pub fn open(dir: &Path) -> Result<ShardRun> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: invalid JSON: {e}", path.display()))?;
        fn field<'a>(doc: &'a Json, path: &Path, key: &str) -> Result<&'a Json> {
            doc.get(key)
                .ok_or_else(|| anyhow::anyhow!("{}: missing field '{key}'", path.display()))
        }
        fn as_usize(doc: &Json, path: &Path, key: &str) -> Result<usize> {
            field(doc, path, key)?
                .as_u64()
                .map(|v| v as usize)
                .ok_or_else(|| anyhow::anyhow!("{}: field '{key}' not a count", path.display()))
        }
        fn as_string(doc: &Json, path: &Path, key: &str) -> Result<String> {
            field(doc, path, key)?
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("{}: field '{key}' not a string", path.display()))
        }
        let format = field(&doc, &path, "format")?.as_u64().unwrap_or(0);
        if !(MANIFEST_FORMAT_MIN..=MANIFEST_FORMAT).contains(&format) {
            bail!(
                "{}: manifest format {format} unsupported (reader speaks \
                 {MANIFEST_FORMAT_MIN}..={MANIFEST_FORMAT})",
                path.display()
            );
        }
        let completed = match field(&doc, &path, "levels_complete")?.as_i64() {
            Some(v) if v >= 0 => Some(v as usize),
            Some(_) => None,
            None => bail!("{}: field 'levels_complete' not an integer", path.display()),
        };
        let run = ShardRun {
            dir: dir.to_path_buf(),
            p: as_usize(&doc, &path, "p")?,
            n: as_usize(&doc, &path, "n")?,
            shards: as_usize(&doc, &path, "shards")?,
            mask_bytes: as_usize(&doc, &path, "mask_bytes")?,
            score: as_string(&doc, &path, "score")?,
            fingerprint: as_string(&doc, &path, "fingerprint")?,
            // v2 field; v1 manifests were single-host by construction
            hosts: doc
                .get("hosts")
                .and_then(Json::as_u64)
                .map_or(1, |h| (h as usize).max(1)),
            completed,
        };
        if !run.shards.is_power_of_two() || run.shards == 0 {
            bail!(
                "{}: manifest shard count {} is not a power of two",
                path.display(),
                run.shards
            );
        }
        if let Some(k) = run.completed {
            if k > run.p {
                bail!(
                    "{}: manifest claims level {k} complete but p = {}",
                    path.display(),
                    run.p
                );
            }
        }
        Ok(run)
    }

    /// Atomically rewrite the manifest from this handle's in-memory
    /// state without advancing it — the cluster barrier's repair hook
    /// for a manifest that regressed when a stalled committer's rename
    /// landed late (see `coordinator::cluster::commit_checked`).
    pub(crate) fn rewrite_manifest(&self) -> Result<()> {
        self.write_manifest()
    }

    fn write_manifest(&self) -> Result<()> {
        let doc = Json::obj()
            .set("format", MANIFEST_FORMAT)
            .set("p", self.p)
            .set("n", self.n)
            .set("shards", self.shards)
            .set("mask_bytes", self.mask_bytes)
            .set("score", self.score.as_str())
            .set("fingerprint", self.fingerprint.as_str())
            .set("hosts", self.hosts)
            .set(
                "levels_complete",
                self.completed.map(|k| k as i64).unwrap_or(-1),
            );
        let path = self.dir.join("manifest.json");
        // the tmp name is unique per writer AND per write: in cluster
        // mode two hosts may rewrite the manifest concurrently (a benign
        // commit race — the contents are identical), and a shared tmp
        // name would let one writer rename the other's half-written file
        // into place. The sequence number covers in-process "hosts"
        // (worker threads in the tests), which share a pid.
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let tmp = self.dir.join(format!(
            "manifest.json.tmp.{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        {
            // write + fsync BEFORE the rename: a rename whose data blocks
            // never hit disk would survive a crash as a garbage manifest
            let mut file = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            file.write_all(doc.to_pretty().as_bytes())
                .with_context(|| format!("writing {}", tmp.display()))?;
            file.sync_all()
                .with_context(|| format!("syncing {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {}", path.display()))?;
        // best-effort directory fsync so the rename itself is durable
        if let Ok(dir) = File::open(&self.dir) {
            let _ = dir.sync_all();
        }
        Ok(())
    }

    /// Durably mark level `k` complete (atomic manifest rewrite). All of
    /// the level's shard files must be flushed before this is called.
    /// Levels commit strictly in order: committing a level at or below
    /// `completed` (a double commit) or skipping ahead is an error, not a
    /// silent rewrite — the cluster barrier relies on this to reject a
    /// confused committer.
    pub fn commit_level(&mut self, k: usize) -> Result<()> {
        let expect = self.completed.map_or(0, |c| c + 1);
        if k != expect {
            match self.completed {
                Some(c) if k <= c => bail!(
                    "{}: level {k} is already committed (double commit \
                     rejected; levels_complete = {c})",
                    self.dir.join("manifest.json").display()
                ),
                _ => bail!(
                    "{}: cannot commit level {k} out of order — the next \
                     committable level is {expect}",
                    self.dir.join("manifest.json").display()
                ),
            }
        }
        self.completed = Some(k);
        self.write_manifest()
    }

    /// Shard partition of level `k`.
    pub fn spec(&self, binom: &BinomTable, k: usize) -> ShardSpec {
        ShardSpec::new(binom.c(self.p, k), self.shards)
    }

    /// Path of one shard file: `level_{k}_shard_{s}.{ext}`.
    pub fn shard_file(&self, k: usize, s: usize, ext: &str) -> PathBuf {
        self.dir.join(format!("level_{k:02}_shard_{s:04}.{ext}"))
    }

    /// Drop the `.bps`/`.qr` files of a level that is no longer needed
    /// for resume (its successor has committed). `.sink` files stay:
    /// reconstruction reads one record per level at the very end.
    pub fn prune_level(&self, k: usize) {
        for s in 0..self.shards {
            let _ = std::fs::remove_file(self.shard_file(k, s, "bps"));
            let _ = std::fs::remove_file(self.shard_file(k, s, "qr"));
        }
    }
}

/// Receives one sink record per subset, in colex order — the level sweep
/// is generic over whether sinks land in the in-RAM `2^p` tables
/// (unsharded solver) or a per-shard stream buffer ([`SinkBuf`]).
pub trait SinkOut<M: VarMask> {
    fn put(&mut self, mask: M, sink: u8, pmask: M);
}

/// Buffered sink records for one shard batch (flushed to the `.sink`
/// file by [`ShardWriterSet::append`]).
pub struct SinkBuf<M: VarMask> {
    buf: Vec<u8>,
    _width: PhantomData<M>,
}

impl<M: VarMask> Default for SinkBuf<M> {
    fn default() -> SinkBuf<M> {
        SinkBuf {
            buf: Vec::new(),
            _width: PhantomData,
        }
    }
}

impl<M: VarMask> SinkOut<M> for SinkBuf<M> {
    #[inline]
    fn put(&mut self, _mask: M, sink: u8, pmask: M) {
        self.buf.push(sink);
        self.buf
            .extend_from_slice(&pmask.to_u64().to_le_bytes()[..M::BYTES]);
    }
}

/// The one-spill-writer-per-shard bundle: `.bps` + `.qr` + `.sink`
/// streams for one (level, shard) pair, appended batch by batch so a
/// shard's frontier never materialises in RAM.
///
/// Single-host runs write the canonical `level_*_shard_*.{ext}` files
/// directly ([`ShardWriterSet::create`]). Cluster hosts write *staged*
/// files (`.{ext}.host-…` — [`ShardWriterSet::create_staged`]) that
/// [`ShardWriterSet::finish`] renames into place only after the fsync,
/// so a host whose claim was reclaimed mid-write (a "zombie") can never
/// leave a truncated canonical file: either its rename never happens, or
/// it atomically publishes bytes that are bit-identical to the
/// reclaimer's (the sweep is deterministic).
pub struct ShardWriterSet<M: VarMask> {
    bps: BufWriter<File>,
    qr: BufWriter<File>,
    sink: BufWriter<File>,
    /// `(written path, canonical path)` per stream; equal when unstaged.
    publish: [(PathBuf, PathBuf); 3],
    entries: u64,
    bytes: u64,
    _width: PhantomData<M>,
}

impl<M: VarMask> ShardWriterSet<M> {
    /// Write the canonical shard files directly (single-host path).
    pub fn create(run: &ShardRun, k: usize, s: usize) -> Result<ShardWriterSet<M>> {
        ShardWriterSet::create_inner(run, k, s, None)
    }

    /// Write host-unique staged files, atomically renamed to the
    /// canonical names by [`ShardWriterSet::finish`] (cluster path).
    /// `tag` must be unique per writing process (e.g. `host-0003-71234`).
    pub fn create_staged(
        run: &ShardRun,
        k: usize,
        s: usize,
        tag: &str,
    ) -> Result<ShardWriterSet<M>> {
        ShardWriterSet::create_inner(run, k, s, Some(tag))
    }

    fn create_inner(
        run: &ShardRun,
        k: usize,
        s: usize,
        tag: Option<&str>,
    ) -> Result<ShardWriterSet<M>> {
        let mut publish: Vec<(PathBuf, PathBuf)> = Vec::with_capacity(3);
        let mut open = |ext: &str, kind: u8| -> Result<BufWriter<File>> {
            let target = run.shard_file(k, s, ext);
            let path = match tag {
                Some(tag) => {
                    let mut name = target.as_os_str().to_os_string();
                    name.push(format!(".{tag}"));
                    PathBuf::from(name)
                }
                None => target.clone(),
            };
            let file = File::create(&path)
                .with_context(|| format!("creating shard file {}", path.display()))?;
            let mut w = BufWriter::new(file);
            w.write_all(&encode_header(M::BYTES as u8, k as u8, kind))
                .with_context(|| format!("writing header of {}", path.display()))?;
            publish.push((path, target));
            Ok(w)
        };
        let bps = open("bps", KIND_BPS)?;
        let qr = open("qr", KIND_QR)?;
        let sink = open("sink", KIND_SINK)?;
        let publish: [(PathBuf, PathBuf); 3] =
            publish.try_into().expect("three shard streams");
        Ok(ShardWriterSet {
            bps,
            qr,
            sink,
            publish,
            entries: 0,
            bytes: 3 * HEADER as u64,
            _width: PhantomData,
        })
    }

    /// Append one computed batch: `take` subsets' `q`/`r`, their
    /// `take·k` best-parent records, and the batch's buffered sink
    /// records (cleared after the flush).
    pub fn append(
        &mut self,
        q: &[f64],
        r: &[f64],
        bps: &[f64],
        bpm: &[M],
        sinks: &mut SinkBuf<M>,
    ) -> Result<()> {
        debug_assert_eq!(q.len(), r.len());
        debug_assert_eq!(bps.len(), bpm.len());
        for i in 0..q.len() {
            self.qr.write_all(&q[i].to_le_bytes())?;
            self.qr.write_all(&r[i].to_le_bytes())?;
        }
        for i in 0..bps.len() {
            self.bps.write_all(&bps[i].to_le_bytes())?;
            self.bps
                .write_all(&bpm[i].to_u64().to_le_bytes()[..M::BYTES])?;
        }
        self.sink.write_all(&sinks.buf)?;
        self.bytes += (q.len() * QR_RECORD
            + bps.len() * record_bytes::<M>()
            + sinks.buf.len()) as u64;
        sinks.buf.clear();
        self.entries += q.len() as u64;
        Ok(())
    }

    /// Flush + fsync all three streams, then (for staged writers) rename
    /// them to their canonical names; returns (subset entries, bytes
    /// written). Sync errors propagate: the level must not commit over
    /// shard data the kernel could not persist, and a staged file is
    /// only published after its bytes are durable.
    pub fn finish(self) -> Result<(u64, u64)> {
        for mut w in [self.bps, self.qr, self.sink] {
            w.flush()?;
            w.get_ref().sync_data()?;
        }
        for (written, target) in &self.publish {
            if written != target {
                std::fs::rename(written, target).with_context(|| {
                    format!("publishing shard file {}", target.display())
                })?;
            }
        }
        Ok((self.entries, self.bytes))
    }
}

/// A direct-mapped window cache over one fixed-record-size shard file
/// (the read half of the format; each worker opens its own, so no
/// cross-thread sharing).
struct WindowedRecords {
    file: RefCell<File>,
    cache: RefCell<WindowCache>,
    path: String,
    record: usize,
    entries: usize,
    slots: usize,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

struct WindowCache {
    tags: Vec<i64>,
    data: Vec<u8>,
}

impl WindowedRecords {
    /// Open + fully validate one shard file: v1 header fields *and* the
    /// exact byte length implied by `entries` (a truncated or corrupt
    /// shard fails here, by path, before any rank is served).
    fn open(
        path: &Path,
        width_bytes: usize,
        k: usize,
        kind: u8,
        record: usize,
        entries: usize,
        slots_budget: usize,
    ) -> Result<WindowedRecords> {
        let mut file =
            File::open(path).with_context(|| format!("opening shard file {}", path.display()))?;
        let mut header = [0u8; HEADER];
        file.read_exact(&mut header)
            .with_context(|| format!("reading header of {}", path.display()))?;
        decode_header(&header, width_bytes, k, kind, &path.display().to_string())?;
        let expect_len = (HEADER + entries * record) as u64;
        let actual = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        if actual != expect_len {
            bail!(
                "{}: shard file is {actual} bytes but {expect_len} were expected \
                 ({entries} records of {record} bytes + {HEADER}-byte header) — \
                 the file is truncated or from a different run",
                path.display()
            );
        }
        let slots = slots_budget.min(entries.div_ceil(WINDOW)).max(1);
        Ok(WindowedRecords {
            file: RefCell::new(file),
            cache: RefCell::new(WindowCache {
                tags: vec![-1; slots],
                data: vec![0; slots * WINDOW * record],
            }),
            path: path.display().to_string(),
            record,
            entries,
            slots,
            hits: Cell::new(0),
            misses: Cell::new(0),
        })
    }

    fn resident_bytes(&self) -> usize {
        self.slots * WINDOW * self.record + self.slots * 8
    }

    /// Copy record `idx` into `out[..record]` through the window cache.
    #[inline]
    fn read_into(&self, idx: usize, out: &mut [u8]) {
        debug_assert!(idx < self.entries, "{}: record {idx} out of range", self.path);
        let record = self.record;
        let window = idx / WINDOW;
        let within = idx % WINDOW;
        let slot = window % self.slots;
        let mut cache = self.cache.borrow_mut();
        if cache.tags[slot] != window as i64 {
            self.misses.set(self.misses.get() + 1);
            let start = window * WINDOW;
            let len = WINDOW.min(self.entries - start);
            let mut file = self.file.borrow_mut();
            // I/O failures after open-time validation are unrecoverable
            // mid-sweep (the hot read path returns values, not Results);
            // name the file so the abort is actionable.
            file.seek(SeekFrom::Start((HEADER + start * record) as u64))
                .unwrap_or_else(|e| panic!("{}: seek to window {window} failed: {e}", self.path));
            let base = slot * WINDOW * record;
            file.read_exact(&mut cache.data[base..base + len * record])
                .unwrap_or_else(|e| panic!("{}: read of window {window} failed: {e}", self.path));
            cache.tags[slot] = window as i64;
        } else {
            self.hits.set(self.hits.get() + 1);
        }
        let off = slot * WINDOW * record + within * record;
        out[..record].copy_from_slice(&cache.data[off..off + record]);
    }
}

/// Read access to one *committed* level across all of its shard files.
///
/// Every worker opens its own reader (own file handles + caches), so the
/// shard-parallel sweep needs no cross-thread synchronisation; colex
/// locality of the drop-one ranks keeps the per-shard window caches hot
/// exactly as in the unsharded spill path.
pub struct ShardedLevelReader<M: VarMask> {
    pub k: usize,
    spec: ShardSpec,
    /// `.qr` reader per shard (`None` for empty shards).
    qr: Vec<Option<WindowedRecords>>,
    /// `.bps` reader per shard (`None` for empty shards and at level 0,
    /// which has no best-parent records).
    bps: Vec<Option<WindowedRecords>>,
    _width: PhantomData<M>,
}

impl<M: VarMask> ShardedLevelReader<M> {
    pub fn open(run: &ShardRun, binom: &BinomTable, k: usize) -> Result<ShardedLevelReader<M>> {
        debug_assert_eq!(run.mask_bytes, M::BYTES);
        let spec = run.spec(binom, k);
        let slots = slot_cap(spec.shards);
        let mut qr = Vec::with_capacity(spec.shards);
        let mut bps = Vec::with_capacity(spec.shards);
        for s in 0..spec.shards {
            let entries = spec.entries(s) as usize;
            if entries == 0 {
                qr.push(None);
                bps.push(None);
                continue;
            }
            qr.push(Some(WindowedRecords::open(
                &run.shard_file(k, s, "qr"),
                M::BYTES,
                k,
                KIND_QR,
                QR_RECORD,
                entries,
                slots,
            )?));
            bps.push(if k == 0 {
                None
            } else {
                Some(WindowedRecords::open(
                    &run.shard_file(k, s, "bps"),
                    M::BYTES,
                    k,
                    KIND_BPS,
                    record_bytes::<M>(),
                    entries * k,
                    slots,
                )?)
            });
        }
        Ok(ShardedLevelReader {
            k,
            spec,
            qr,
            bps,
            _width: PhantomData,
        })
    }

    /// `(log Q, log R)` of the subset at global rank `t` — one windowed
    /// record read (the hot transition loop needs both per drop-rank).
    #[inline]
    pub fn qr_at(&self, t: usize) -> (f64, f64) {
        let (s, local) = self.spec.locate(t as u64);
        let mut buf = [0u8; QR_RECORD];
        self.qr[s]
            .as_ref()
            .expect("rank routed to an empty shard")
            .read_into(local as usize, &mut buf);
        (
            f64::from_le_bytes(buf[..8].try_into().unwrap()),
            f64::from_le_bytes(buf[8..].try_into().unwrap()),
        )
    }

    /// `log Q` of the subset at global rank `t`.
    #[inline]
    pub fn q_at(&self, t: usize) -> f64 {
        self.qr_at(t).0
    }

    /// `log R` of the subset at global rank `t`.
    #[inline]
    pub fn r_at(&self, t: usize) -> f64 {
        self.qr_at(t).1
    }

    /// Best family score + argmax parent mask at flat index `t*k + pos`.
    #[inline]
    pub fn bps_at(&self, idx: usize) -> (f64, M) {
        let t = idx / self.k;
        let pos = idx % self.k;
        let (s, local) = self.spec.locate(t as u64);
        let mut buf = [0u8; 16];
        let record = record_bytes::<M>();
        self.bps[s]
            .as_ref()
            .expect("bps read at level 0 or empty shard")
            .read_into(local as usize * self.k + pos, &mut buf[..record]);
        let score = f64::from_le_bytes(buf[..8].try_into().unwrap());
        let mut raw = [0u8; 8];
        raw[..M::BYTES].copy_from_slice(&buf[8..8 + M::BYTES]);
        (score, M::from_u64(u64::from_le_bytes(raw)))
    }

    /// Resident bytes of this reader's window caches (memory accounting).
    pub fn resident_bytes(&self) -> usize {
        let sum = |files: &[Option<WindowedRecords>]| -> usize {
            files
                .iter()
                .flatten()
                .map(WindowedRecords::resident_bytes)
                .sum()
        };
        sum(&self.qr) + sum(&self.bps)
    }
}

/// Read one record of a shard file without a cache (used a handful of
/// times per run: reconstruction + the final score).
fn read_one_record(
    path: &Path,
    width_bytes: usize,
    k: usize,
    kind: u8,
    record: usize,
    idx: u64,
    out: &mut [u8],
) -> Result<()> {
    let mut file =
        File::open(path).with_context(|| format!("opening shard file {}", path.display()))?;
    let mut header = [0u8; HEADER];
    file.read_exact(&mut header)
        .with_context(|| format!("reading header of {}", path.display()))?;
    decode_header(&header, width_bytes, k, kind, &path.display().to_string())?;
    file.seek(SeekFrom::Start(HEADER as u64 + idx * record as u64))?;
    file.read_exact(&mut out[..record])
        .with_context(|| format!("reading record {idx} of {}", path.display()))?;
    Ok(())
}

/// `log R(V)` of a fully committed run: the single `.qr` record of
/// level `p`.
pub fn final_score<M: VarMask>(run: &ShardRun) -> Result<f64> {
    let spec = ShardSpec::new(1, run.shards);
    let (s, local) = spec.locate(0);
    let mut buf = [0u8; QR_RECORD];
    read_one_record(
        &run.shard_file(run.p, s, "qr"),
        M::BYTES,
        run.p,
        KIND_QR,
        QR_RECORD,
        local,
        &mut buf,
    )?;
    Ok(f64::from_le_bytes(buf[8..].try_into().unwrap()))
}

/// Disk-backed reconstruction (§3 step 4–5): walk the sinks from the
/// full set down to ∅ reading **one** `.sink` record per level, instead
/// of indexing `(1+mask)·2^p` bytes of in-RAM tables — this is what
/// frees the sharded path from the sink-table RAM cap.
pub fn reconstruct_from_disk<M: VarMask>(
    run: &ShardRun,
    binom: &BinomTable,
) -> Result<(Dag, Vec<usize>)> {
    let p = run.p;
    let mut mask = M::low_bits(p);
    let mut parents = vec![0u64; p];
    let mut order_rev = Vec::with_capacity(p);
    let record = sink_record_bytes::<M>();
    let mut buf = [0u8; 9];
    for k in (1..=p).rev() {
        let rank = colex_rank(binom, mask);
        let (s, local) = run.spec(binom, k).locate(rank);
        read_one_record(
            &run.shard_file(k, s, "sink"),
            M::BYTES,
            k,
            KIND_SINK,
            record,
            local,
            &mut buf,
        )?;
        let x = buf[0] as usize;
        let mut raw = [0u8; 8];
        raw[..M::BYTES].copy_from_slice(&buf[1..1 + M::BYTES]);
        let pmask = u64::from_le_bytes(raw);
        // range-check before mask ops: a rotted sink byte must hit the
        // named corruption error below, not a bit-shift/index panic
        if x >= p || !mask.contains(x) {
            bail!(
                "{}: recorded sink X{x} is not in the rank-{rank} subset — \
                 the run directory is corrupt or from a different dataset",
                run.shard_file(k, s, "sink").display()
            );
        }
        if pmask & !mask.without(x).to_u64() != 0 {
            bail!(
                "{}: recorded parent set escapes its subset (rank {rank})",
                run.shard_file(k, s, "sink").display()
            );
        }
        parents[x] = pmask;
        order_rev.push(x);
        mask = mask.without(x);
    }
    order_rev.reverse();
    Ok((Dag::from_parents(parents), order_rev))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bnsl_shard_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn spec_partitions_every_rank_exactly_once() {
        for size in [1u64, 2, 5, 16, 100, 184_756] {
            for shards in [1usize, 2, 4, 8, 64] {
                let spec = ShardSpec::new(size, shards);
                let mut covered = 0u64;
                for s in 0..shards {
                    let (lo, hi) = spec.bounds(s);
                    assert_eq!(lo, covered.min(size), "contiguous");
                    assert!(hi >= lo);
                    covered = hi;
                    for rank in lo..hi.min(lo + 50) {
                        let (s2, local) = spec.locate(rank);
                        assert_eq!(s2, s, "rank {rank} of {size}/{shards}");
                        assert_eq!(local, rank - lo);
                    }
                }
                assert_eq!(covered, size, "all ranks covered");
            }
        }
    }

    #[test]
    fn spec_top_bits_for_power_of_two_sizes() {
        // size 2^10, 4 shards: shard index == top 2 bits of the rank.
        let spec = ShardSpec::new(1024, 4);
        for rank in (0..1024u64).step_by(17) {
            assert_eq!(spec.locate(rank).0 as u64, rank >> 8);
        }
    }

    #[test]
    fn manifest_roundtrip_and_commit() {
        let dir = tmpdir("manifest");
        let opts = ShardOptions {
            shards: 4,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut run =
            ShardRun::open_or_create(&opts, 12, 200, 4, "Jeffreys", "00ff00ff00ff00ff").unwrap();
        assert_eq!(run.completed, None);
        run.commit_level(0).unwrap();
        run.commit_level(1).unwrap();
        let back = ShardRun::open(&dir).unwrap();
        assert_eq!(back.completed, Some(1));
        assert_eq!(back.p, 12);
        assert_eq!(back.shards, 4);
        assert_eq!(back.score, "Jeffreys");
        // resume path: same identity is accepted, shards come from the manifest
        let resumed = ShardRun::open_or_create(
            &ShardOptions {
                shards: 0,
                dir: dir.clone(),
                ..Default::default()
            },
            12,
            200,
            4,
            "Jeffreys",
            "00ff00ff00ff00ff",
        )
        .unwrap();
        assert_eq!(resumed.shards, 4);
        assert_eq!(resumed.completed, Some(1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_v2_records_hosts_and_reads_v1_without_them() {
        let dir = tmpdir("hosts");
        let opts = ShardOptions {
            shards: 2,
            hosts: 3,
            dir: dir.clone(),
            ..Default::default()
        };
        ShardRun::open_or_create(&opts, 9, 50, 4, "Bic", "abcd").unwrap();
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(text.contains("\"format\": 2"), "{text}");
        assert!(text.contains("\"hosts\": 3"), "{text}");
        assert_eq!(ShardRun::open(&dir).unwrap().hosts, 3);
        // a v1 manifest (no hosts field) still opens, defaulting to 1
        let v1 = text
            .replace("\"format\": 2", "\"format\": 1")
            .lines()
            .filter(|l| !l.contains("\"hosts\""))
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(dir.join("manifest.json"), v1).unwrap();
        let back = ShardRun::open(&dir).unwrap();
        assert_eq!(back.hosts, 1);
        // ...and a future format is rejected by version range
        let v9 = text.replace("\"format\": 2", "\"format\": 9");
        std::fs::write(dir.join("manifest.json"), v9).unwrap();
        let err = ShardRun::open(&dir).unwrap_err().to_string();
        assert!(err.contains("format 9"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn staged_writer_publishes_only_at_finish() {
        let dir = tmpdir("staged");
        let opts = ShardOptions {
            shards: 1,
            dir: dir.clone(),
            ..Default::default()
        };
        let run = ShardRun::open_or_create(&opts, 8, 10, 4, "Jeffreys", "ff").unwrap();
        let k = 2;
        let mut w = ShardWriterSet::<u32>::create_staged(&run, k, 0, "host-0001-42").unwrap();
        let mut sinks = SinkBuf::default();
        sinks.put(0u32, 1, 0);
        w.append(&[1.0], &[2.0], &[0.5, 0.25], &[3u32, 5], &mut sinks)
            .unwrap();
        // nothing canonical exists while the writer is staging
        for ext in ["bps", "qr", "sink"] {
            assert!(!run.shard_file(k, 0, ext).exists(), "{ext} published early");
        }
        let (entries, bytes) = w.finish().unwrap();
        assert_eq!(entries, 1);
        assert!(bytes > 0);
        // finish renamed every stream into place and left no staged strays
        for ext in ["bps", "qr", "sink"] {
            let canon = run.shard_file(k, 0, ext);
            assert!(canon.exists(), "{ext} missing after publish");
            let mut staged = canon.as_os_str().to_os_string();
            staged.push(".host-0001-42");
            assert!(!PathBuf::from(staged).exists(), "{ext} stray remains");
        }
        // and the published .qr stream reads back like a direct write
        let bytes = std::fs::read(run.shard_file(k, 0, "qr")).unwrap();
        assert_eq!(bytes.len(), HEADER + QR_RECORD);
        assert_eq!(
            f64::from_le_bytes(bytes[HEADER..HEADER + 8].try_into().unwrap()),
            1.0
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn commit_level_rejects_double_and_out_of_order_commits() {
        let dir = tmpdir("commit_order");
        let opts = ShardOptions {
            shards: 1,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut run = ShardRun::open_or_create(&opts, 6, 10, 4, "Bic", "11").unwrap();
        // skipping ahead is rejected
        let err = run.commit_level(1).unwrap_err().to_string();
        assert!(err.contains("out of order"), "{err}");
        run.commit_level(0).unwrap();
        run.commit_level(1).unwrap();
        // double commit is rejected by name
        let err = run.commit_level(1).unwrap_err().to_string();
        assert!(err.contains("already committed"), "{err}");
        assert_eq!(run.completed, Some(1), "failed commit left state intact");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fd_budget_prices_cluster_margin() {
        assert_eq!(fd_budget(2, 4, false), 2 * 11 + 32);
        assert_eq!(
            fd_budget(2, 4, true),
            fd_budget(2, 4, false) + CLUSTER_FD_MARGIN
        );
    }

    #[test]
    fn manifest_rejects_identity_mismatches_by_name() {
        let dir = tmpdir("mismatch");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        ShardRun::open_or_create(&opts, 10, 100, 4, "Bic", "aaaa").unwrap();
        let err = ShardRun::open_or_create(&opts, 11, 100, 4, "Bic", "aaaa")
            .unwrap_err()
            .to_string();
        assert!(err.contains("p"), "{err}");
        let err = ShardRun::open_or_create(&opts, 10, 100, 4, "Bic", "bbbb")
            .unwrap_err()
            .to_string();
        assert!(err.contains("fingerprint"), "{err}");
        let err = ShardRun::open_or_create(
            &ShardOptions {
                shards: 8,
                dir: dir.clone(),
                ..Default::default()
            },
            10,
            100,
            4,
            "Bic",
            "aaaa",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("shards"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_non_power_of_two_shards() {
        let dir = tmpdir("pow2");
        let err = ShardRun::open_or_create(
            &ShardOptions {
                shards: 3,
                dir: dir.clone(),
                ..Default::default()
            },
            8,
            50,
            4,
            "Jeffreys",
            "cc",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("power of two"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn writer_reader_roundtrip_across_shards() {
        let dir = tmpdir("roundtrip");
        let p = 9;
        let k = 4;
        let binom = BinomTable::new(p);
        let opts = ShardOptions {
            shards: 4,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut run = ShardRun::open_or_create(&opts, p, 10, 4, "Jeffreys", "ee").unwrap();
        for lvl in 0..k {
            run.commit_level(lvl).ok();
        }
        let spec = run.spec(&binom, k);
        let size = spec.size as usize;
        // synthesise a known level: q = rank, r = -rank, bps = rank*k+j,
        // bpm = j-th drop mask stand-in (rank+j as mask bits)
        for s in 0..spec.shards {
            let (lo, hi) = spec.bounds(s);
            if lo >= hi {
                continue;
            }
            let mut w = ShardWriterSet::<u32>::create(&run, k, s).unwrap();
            let mut sinks = SinkBuf::default();
            for t in lo..hi {
                let q = [t as f64];
                let r = [-(t as f64)];
                let bps: Vec<f64> = (0..k).map(|j| (t as usize * k + j) as f64).collect();
                let bpm: Vec<u32> = (0..k).map(|j| (t as u32) ^ (j as u32)).collect();
                sinks.put(0u32, (t % 7) as u8, t as u32);
                w.append(&q, &r, &bps, &bpm, &mut sinks).unwrap();
            }
            let (entries, bytes) = w.finish().unwrap();
            assert_eq!(entries, hi - lo);
            assert!(bytes > 0);
        }
        run.commit_level(k).unwrap();
        let reader = ShardedLevelReader::<u32>::open(&run, &binom, k).unwrap();
        for t in (0..size).step_by(3) {
            assert_eq!(reader.q_at(t), t as f64);
            assert_eq!(reader.r_at(t), -(t as f64));
            for j in 0..k {
                let (s, m) = reader.bps_at(t * k + j);
                assert_eq!(s, (t * k + j) as f64);
                assert_eq!(m, (t as u32) ^ (j as u32));
            }
        }
        assert!(reader.resident_bytes() > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reader_names_corrupt_and_truncated_files() {
        let dir = tmpdir("corrupt");
        let p = 8;
        let k = 3;
        let binom = BinomTable::new(p);
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let run = ShardRun::open_or_create(&opts, p, 10, 4, "Jeffreys", "dd").unwrap();
        let spec = run.spec(&binom, k);
        for s in 0..spec.shards {
            let (lo, hi) = spec.bounds(s);
            let mut w = ShardWriterSet::<u32>::create(&run, k, s).unwrap();
            let mut sinks = SinkBuf::default();
            for t in lo..hi {
                sinks.put(0u32, 0, 0);
                w.append(
                    &[0.0],
                    &[0.0],
                    &vec![0.0; k],
                    &vec![0u32; k],
                    &mut sinks,
                )
                .unwrap();
            }
            w.finish().unwrap();
        }
        // flip a header byte of shard 1's bps file
        let victim = run.shard_file(k, 1, "bps");
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        let err = ShardedLevelReader::<u32>::open(&run, &binom, k)
            .unwrap_err()
            .to_string();
        assert!(
            err.contains(&victim.display().to_string()),
            "error names the corrupt file: {err}"
        );
        assert!(err.contains("magic"), "{err}");
        // restore the header but truncate the tail: length check fires
        bytes[0] ^= 0xFF;
        bytes.truncate(bytes.len() - 1);
        std::fs::write(&victim, &bytes).unwrap();
        let err = ShardedLevelReader::<u32>::open(&run, &binom, k)
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_distinguishes_data_and_score() {
        let a = synth::binary(5, 40, 1);
        let b = synth::binary(5, 40, 2);
        let fa = run_fingerprint(&a, ScoreKind::Jeffreys);
        assert_eq!(fa, run_fingerprint(&a, ScoreKind::Jeffreys), "stable");
        assert_ne!(fa, run_fingerprint(&b, ScoreKind::Jeffreys), "data-sensitive");
        assert_ne!(
            fa,
            run_fingerprint(&a, ScoreKind::Bic),
            "score-sensitive"
        );
        assert_ne!(
            run_fingerprint(&a, ScoreKind::Bdeu { ess: 1.0 }),
            run_fingerprint(&a, ScoreKind::Bdeu { ess: 2.0 }),
            "hyperparameter-sensitive"
        );
        assert_eq!(fa.len(), 16, "16 hex chars");
    }

    #[test]
    fn reader_cache_is_bounded_by_file_size_and_shard_count() {
        // tiny shard: one window, not SLOTS of them
        assert!(reader_cache_bytes(10, 12, 1) <= WINDOW * 12 + 8);
        // huge shard, one shard: capped at SLOTS windows
        assert_eq!(
            reader_cache_bytes(100 * SLOTS * WINDOW, 12, 1),
            SLOTS * WINDOW * 12 + SLOTS * 8
        );
        // the slot budget divides across shards, so aggregate cache is
        // constant in the shard count
        let total_4: usize = (0..4).map(|_| reader_cache_bytes(usize::MAX / 256, 12, 4)).sum();
        assert_eq!(total_4, SLOTS * WINDOW * 12 + SLOTS * 8);
        // and never collapses to zero
        assert!(reader_cache_bytes(1, 16, 1024) >= WINDOW * 16);
    }
}
