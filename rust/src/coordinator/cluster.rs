//! Multi-host cluster coordination over shared storage — the claim
//! ledger behind [`crate::solver::solve_clustered`].
//!
//! The sharded coordinator ([`crate::coordinator::shard`]) already made
//! the frontier host-agnostic: every level is a set of shard files plus
//! one atomically-committed `manifest.json`. This module adds the piece
//! that lets **N independent `bnsl` processes — on one machine or many,
//! sharing only a filesystem** — cooperate on one solve:
//!
//! * **Claims.** A host takes a (level, shard) pair by creating
//!   `claim-<level>-<shard>.json` with `O_CREAT|O_EXCL` — atomic on any
//!   POSIX filesystem (NFSv3 callers should mount with proper `O_EXCL`
//!   support or use v4). The claim records host id, pid and the owner's
//!   heartbeat cadence.
//! * **Heartbeats.** While computing, the owner rewrites its claim file
//!   (refreshing the mtime) at least twice per heartbeat interval. A
//!   claim whose mtime is older than `4 ×` its recorded cadence is
//!   *stale*: the owner is presumed dead and the work is re-runnable.
//! * **Reclaim.** Stealing a stale claim is a rename to a
//!   contender-unique name — exactly one host's rename succeeds — after
//!   which the winner re-creates the claim as its own. A SIGKILLed
//!   host's unfinished shards are therefore re-run, not lost; its
//!   *finished* shards survive via fsynced `done-<level>-<shard>.json`
//!   markers and are never recomputed.
//! * **Zombie safety.** A host that lost its claim but keeps computing
//!   writes only to staged files
//!   ([`crate::coordinator::shard::ShardWriterSet::create_staged`]) and
//!   publishes by atomic rename. Because every execution mode of the
//!   sweep is bit-identical (the repo's core invariant), a zombie's
//!   publish writes the same bytes the reclaimer produced — a stale
//!   writer can overwrite, but never corrupt.
//! * **Barrier + election.** A level commits when every non-empty shard
//!   has a done marker. Each host that observes this writes
//!   `finish-<level>-host-<id>.json`; the **lowest host id among the
//!   finish markers present** performs the existing fsynced
//!   [`crate::coordinator::shard::ShardRun::commit_level`] rewrite.
//!   If the elected committer dies first, any host commits after a
//!   stale-interval fallback; the benign double-commit race writes
//!   identical manifests through per-writer temp files, and genuinely
//!   out-of-order commits are rejected by `commit_level` itself.
//! * **Resume.** The manifest stays the durability boundary: any
//!   surviving or restarted host re-enters at `levels_complete + 1`
//!   and the ledger replays only the in-flight level's missing shards —
//!   `--resume` semantics compose unchanged.
//!
//! File-level schemas live in
//! [`docs/FORMATS.md`](https://github.com/paper-repo-growth/bnsl/blob/main/docs/FORMATS.md)
//! (in-tree: `docs/FORMATS.md`); the protocol walkthrough is in
//! [`docs/ARCHITECTURE.md`](https://github.com/paper-repo-growth/bnsl/blob/main/docs/ARCHITECTURE.md)
//! (in-tree: `docs/ARCHITECTURE.md`).

use super::shard::{ShardOptions, ShardRun, ShardSpec};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Per-process sequence for stage tags: same-process workers (and a
/// worker re-claiming its stalled sibling's shard) must never share a
/// staged file name, or one writer's `File::create` would truncate the
/// other's in-flight stream.
static STAGE_SEQ: AtomicU64 = AtomicU64::new(0);

/// A claim is stale once its mtime is older than this many heartbeat
/// intervals — generous enough to ride out scheduler hiccups, small
/// enough that a SIGKILLed host's shard is re-run promptly.
pub const STALE_FACTOR: u32 = 4;

/// Tuning for one cluster host (see [`crate::solver::solve_clustered`]).
#[derive(Clone, Debug)]
pub struct ClusterOptions {
    /// The underlying sharded-run options (shard count, worker pool,
    /// batch size, run directory, checkpointing).
    pub shard: ShardOptions,
    /// This host's id — ties are broken and the committer elected by
    /// *lowest id*, so ids should be distinct across live hosts (a
    /// restarted host reuses its id safely). The declared pool size
    /// lives in [`ShardOptions::hosts`] (one source of truth — it is
    /// what the manifest records).
    pub host_id: usize,
    /// Claim heartbeat cadence. Claims older than
    /// [`STALE_FACTOR`]`× heartbeat` are reclaimable, so this bounds how
    /// long a dead host's shard stays orphaned. Must exceed the shared
    /// filesystem's mtime granularity by a comfortable margin.
    pub heartbeat: Duration,
    /// Sleep between ledger polls while waiting on other hosts.
    pub poll: Duration,
}

impl Default for ClusterOptions {
    fn default() -> ClusterOptions {
        ClusterOptions {
            shard: ShardOptions::default(),
            host_id: 0,
            heartbeat: Duration::from_secs(30),
            poll: Duration::from_millis(500),
        }
    }
}

impl ClusterOptions {
    /// Age past which a claim (or the init lock, or a silent committer)
    /// is treated as dead.
    pub fn stale_after(&self) -> Duration {
        self.heartbeat * STALE_FACTOR
    }
}

/// Outcome of one [`ClaimLedger::try_claim`] attempt.
#[derive(Debug)]
pub enum ClaimState {
    /// This host now owns the shard and must compute + publish it.
    Claimed(Claim),
    /// The shard's done marker exists — nothing to do.
    Done,
    /// Another host holds a live claim; re-poll later.
    Busy,
}

/// A live claim on one (level, shard) pair — the ownership token a
/// worker heartbeats while computing and redeems with
/// [`ClaimLedger::mark_done`].
#[derive(Debug)]
pub struct Claim {
    pub level: usize,
    pub shard: usize,
    path: PathBuf,
    last_beat: Instant,
}

impl Claim {
    /// Refresh the claim's mtime if half a heartbeat has elapsed (cheap
    /// no-op otherwise — callers tick this once per batch). The refresh
    /// is a **pure mtime touch** — `set_modified` on an existing file,
    /// never a content write and never `create` — so there is no window
    /// in which a waking zombie could truncate or overwrite a claim a
    /// reclaimer now owns: at worst it keeps the reclaimer's live claim
    /// fresh (which the reclaimer's own heartbeat does anyway), and a
    /// deleted claim is never resurrected.
    pub fn heartbeat_if_due(&mut self, ledger: &ClaimLedger) {
        if self.last_beat.elapsed() * 2 < ledger.heartbeat {
            return;
        }
        self.last_beat = Instant::now();
        if let Ok(file) = File::options().write(true).open(&self.path) {
            let _ = file.set_modified(std::time::SystemTime::now());
        }
    }
}

/// The per-run claim ledger: one host's handle on the shared-directory
/// claim / done / finish files of an in-flight level.
pub struct ClaimLedger {
    dir: PathBuf,
    host: usize,
    heartbeat: Duration,
    /// Stage-tag prefix for this process's shard writers:
    /// `host-<id>-<pid>`, unique across live processes even when a host
    /// id is reused after a restart.
    stage_prefix: String,
}

impl ClaimLedger {
    pub fn new(dir: &Path, host: usize, heartbeat: Duration) -> ClaimLedger {
        ClaimLedger {
            dir: dir.to_path_buf(),
            host,
            heartbeat,
            stage_prefix: format!("host-{host:04}-{}", std::process::id()),
        }
    }

    pub fn host(&self) -> usize {
        self.host
    }

    /// A fresh writer-unique suffix for one claimed shard's staged
    /// files: `host-<id>-<pid>-<seq>`. The sequence is what keeps a
    /// *same-process* stale-claim steal safe — without it, a sibling
    /// worker reclaiming a stalled worker's shard would `File::create`
    /// (truncate) the very staged file the stalled writer still holds
    /// open, and the interleaved streams could get published.
    pub fn fresh_stage_tag(&self) -> String {
        format!(
            "{}-{}",
            self.stage_prefix,
            STAGE_SEQ.fetch_add(1, Ordering::Relaxed)
        )
    }

    fn claim_path(&self, k: usize, s: usize) -> PathBuf {
        self.dir.join(format!("claim-{k:02}-{s:04}.json"))
    }

    fn done_path(&self, k: usize, s: usize) -> PathBuf {
        self.dir.join(format!("done-{k:02}-{s:04}.json"))
    }

    fn finish_path(&self, k: usize, host: usize) -> PathBuf {
        self.dir.join(format!("finish-{k:02}-host-{host:04}.json"))
    }

    /// Attempt to take (level `k`, shard `s`): done markers win, then a
    /// create-exclusive claim, then a stale-claim steal; anything else is
    /// [`ClaimState::Busy`].
    pub fn try_claim(&self, k: usize, s: usize) -> Result<ClaimState> {
        if self.done_path(k, s).exists() {
            return Ok(ClaimState::Done);
        }
        let path = self.claim_path(k, s);
        match self.create_claim(&path, k, s) {
            Ok(claim) => Ok(ClaimState::Claimed(claim)),
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                if self.claim_is_stale(&path) {
                    // rename-steal: of all contenders observing the same
                    // stale claim, exactly one rename succeeds
                    let steal = self.dir.join(format!(
                        "claim-{k:02}-{s:04}.stale-{}-{}",
                        self.host,
                        std::process::id()
                    ));
                    if std::fs::rename(&path, &steal).is_ok() {
                        let _ = std::fs::remove_file(&steal);
                        if let Ok(claim) = self.create_claim(&path, k, s) {
                            return Ok(ClaimState::Claimed(claim));
                        }
                    }
                }
                Ok(ClaimState::Busy)
            }
            Err(e) => Err(e).with_context(|| format!("creating claim {}", path.display())),
        }
    }

    fn create_claim(&self, path: &Path, k: usize, s: usize) -> std::io::Result<Claim> {
        let mut file = File::options().write(true).create_new(true).open(path)?;
        let body = Json::obj()
            .set("format", 1u64)
            .set("level", k)
            .set("shard", s)
            .set("host", self.host)
            .set("pid", std::process::id())
            .set("heartbeat_secs", self.heartbeat.as_secs_f64())
            .to_pretty();
        file.write_all(body.as_bytes())?;
        Ok(Claim {
            level: k,
            shard: s,
            path: path.to_path_buf(),
            last_beat: Instant::now(),
        })
    }

    /// A claim is stale when its mtime is older than [`STALE_FACTOR`] ×
    /// the cadence *the claim itself recorded* (falling back to ours for
    /// unreadable claims), so hosts with different `--heartbeat-secs`
    /// judge each other by the owner's contract, not their own.
    ///
    /// Clock skew: mtimes are stamped by the filesystem (an NFS server's
    /// clock), `now` by the observer. A small future-dated mtime is
    /// tolerated as fresh, but one further in the future than the stale
    /// window itself is treated as *stale-eligible* — a spurious steal
    /// merely duplicates deterministic work (zombie-safe), whereas
    /// "future means fresh forever" would let an absurdly skewed mtime
    /// orphan a dead host's shard indefinitely.
    fn claim_is_stale(&self, path: &Path) -> bool {
        let Ok(meta) = std::fs::metadata(path) else {
            return false;
        };
        let Ok(mtime) = meta.modified() else {
            return false;
        };
        let cadence = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|doc| doc.get("heartbeat_secs").and_then(Json::as_f64))
            .filter(|h| h.is_finite() && *h > 0.0)
            // clamp before Duration::from_secs_f64, which panics on
            // out-of-range values — a foreign/corrupt-but-parsable
            // cadence must not be able to crash every scanning host
            .map_or(self.heartbeat, |h| {
                Duration::from_secs_f64(h.min(86_400.0))
            });
        let window = cadence * STALE_FACTOR;
        match mtime.elapsed() {
            Ok(age) => age > window,
            // mtime in the observer's future by `skew`
            Err(e) => e.duration() > window,
        }
    }

    /// Durably record a computed shard: the done marker is written
    /// tmp-then-rename and fsynced *after* the shard files themselves
    /// were synced and published, so a marker never vouches for bytes
    /// the kernel could lose. The claim file is then released.
    pub fn mark_done(&self, claim: &Claim, entries: u64, bytes: u64) -> Result<()> {
        let done = self.done_path(claim.level, claim.shard);
        let tmp = self.dir.join(format!(
            "done-{:02}-{:04}.tmp-{}-{}",
            claim.level,
            claim.shard,
            self.host,
            std::process::id()
        ));
        let doc = Json::obj()
            .set("level", claim.level)
            .set("shard", claim.shard)
            .set("host", self.host)
            .set("entries", entries)
            .set("bytes", bytes);
        {
            let mut file = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            file.write_all(doc.to_pretty().as_bytes())
                .with_context(|| format!("writing {}", tmp.display()))?;
            file.sync_all()
                .with_context(|| format!("syncing {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &done)
            .with_context(|| format!("publishing {}", done.display()))?;
        if let Ok(dir) = File::open(&self.dir) {
            let _ = dir.sync_all();
        }
        self.release(claim);
        Ok(())
    }

    /// Does the claim file at `path` still record this host and process?
    /// Checked before unlinking, so a zombie whose claim was stolen
    /// cannot delete the reclaimer's live claim out from under it.
    fn owns_claim(&self, path: &Path) -> bool {
        std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .is_some_and(|doc| {
                doc.get("host").and_then(Json::as_u64) == Some(self.host as u64)
                    && doc.get("pid").and_then(Json::as_u64)
                        == Some(u64::from(std::process::id()))
            })
    }

    /// Release an unredeemed claim (abandoning the shard, e.g. when the
    /// level turned out to be superseded) — but only if it is still
    /// ours; a stolen claim belongs to its reclaimer now.
    pub fn release(&self, claim: &Claim) {
        if self.owns_claim(&claim.path) {
            let _ = std::fs::remove_file(&claim.path);
        }
    }

    /// Every non-empty shard of level `k` has a done marker.
    pub fn all_done(&self, spec: &ShardSpec, k: usize) -> bool {
        (0..spec.shards).all(|s| spec.entries(s) == 0 || self.done_path(k, s).exists())
    }

    /// Announce this host finished its share of level `k` (idempotent).
    pub fn announce_finished(&self, k: usize) -> Result<()> {
        let path = self.finish_path(k, self.host);
        let doc = Json::obj()
            .set("level", k)
            .set("host", self.host)
            .set("pid", std::process::id());
        std::fs::write(&path, doc.to_pretty())
            .with_context(|| format!("writing finish marker {}", path.display()))
    }

    /// Lowest host id among level `k`'s finish markers (`None` before
    /// any host announced) — the committer election.
    pub fn lowest_finisher(&self, k: usize) -> Result<Option<usize>> {
        let prefix = format!("finish-{k:02}-host-");
        let mut lowest: Option<usize> = None;
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing ledger dir {}", self.dir.display()))?
        {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else {
                continue;
            };
            let Some(rest) = name.strip_prefix(&prefix) else {
                continue;
            };
            let Some(id) = rest
                .strip_suffix(".json")
                .and_then(|digits| digits.parse::<usize>().ok())
            else {
                continue;
            };
            lowest = Some(lowest.map_or(id, |low| low.min(id)));
        }
        Ok(lowest)
    }
}

/// Best-effort removal of abandoned `manifest.json.tmp.*` files older
/// than `older_than` (crashed committers leave one per crash; live
/// commits hold theirs for milliseconds).
fn sweep_manifest_temps(dir: &Path, older_than: Duration) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else {
            continue;
        };
        if !name.starts_with("manifest.json.tmp.") {
            continue;
        }
        let old = entry
            .metadata()
            .and_then(|m| m.modified())
            .ok()
            .and_then(|m| m.elapsed().ok())
            .is_some_and(|age| age > older_than);
        if old {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

/// `levels_complete` as currently on disk: `Some(-1)` for a manifest
/// with nothing committed, `None` when the manifest is unreadable
/// (transient mid-rename reads included).
pub fn committed_level(dir: &Path) -> Option<i64> {
    let run = ShardRun::open(dir).ok()?;
    Some(run.completed.map_or(-1, |c| c as i64))
}

/// [`committed_level`], but riding out transiently unreadable manifests
/// (a concurrent commit's rename, an NFS attribute-cache miss) for up to
/// `grace`. For one-shot decisions — "is this failure survivable because
/// the level was superseded?" — where a single unlucky read must not
/// turn a rejoin into a fatal error. Returns `None` only if the manifest
/// stayed unreadable through the whole window.
pub fn committed_level_patient(dir: &Path, grace: Duration, poll: Duration) -> Option<i64> {
    let start = Instant::now();
    loop {
        if let Some(c) = committed_level(dir) {
            return Some(c);
        }
        if start.elapsed() > grace {
            return None;
        }
        std::thread::sleep(poll);
    }
}

/// Open the shared run, creating it exactly once across the cluster: the
/// first host to win the create-exclusive `cluster-init.lock` writes the
/// manifest; everyone else waits for it to appear and then takes the
/// ordinary validate-and-resume path. A lock whose holder died (stale
/// mtime) is removed and re-contested.
pub fn open_or_create_shared(
    options: &ClusterOptions,
    p: usize,
    n: usize,
    mask_bytes: usize,
    score: &str,
    fingerprint: &str,
) -> Result<ShardRun> {
    let dir = &options.shard.dir;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating shard dir {}", dir.display()))?;
    // a committer SIGKILLed between its temp write and its rename leaves
    // a manifest.json.tmp.<pid>.<seq> stray per crash; sweep old ones on
    // the way in (never young ones — a live commit's temp exists only
    // for milliseconds, so the stale window is a generous bound)
    sweep_manifest_temps(dir, options.stale_after());
    let lock = dir.join("cluster-init.lock");
    let started = Instant::now();
    // ample for "another host is writing a two-kilobyte manifest"
    let deadline = options.stale_after() * 4 + Duration::from_secs(10);
    loop {
        if dir.join("manifest.json").exists() {
            return ShardRun::open_or_create(&options.shard, p, n, mask_bytes, score, fingerprint);
        }
        match File::options().write(true).create_new(true).open(&lock) {
            Ok(mut file) => {
                let _ = file.write_all(
                    Json::obj()
                        .set("host", options.host_id)
                        .set("pid", std::process::id())
                        .to_pretty()
                        .as_bytes(),
                );
                drop(file);
                let run = ShardRun::open_or_create(
                    &options.shard,
                    p,
                    n,
                    mask_bytes,
                    score,
                    fingerprint,
                );
                let _ = std::fs::remove_file(&lock);
                return run;
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                // another host is initialising; steal only a dead lock,
                // and steal by rename so exactly one contender wins — a
                // blind remove_file keyed on an earlier stat could delete
                // a *fresh* lock the winner just re-created, letting two
                // hosts initialise (and one later regress) the manifest
                let age = std::fs::metadata(&lock)
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|m| m.elapsed().ok())
                    .unwrap_or(Duration::ZERO);
                if age > options.stale_after() {
                    let steal = dir.join(format!(
                        "cluster-init.lock.stale-{}-{}",
                        options.host_id,
                        std::process::id()
                    ));
                    if std::fs::rename(&lock, &steal).is_ok() {
                        let _ = std::fs::remove_file(&steal);
                    }
                    continue;
                }
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("creating init lock {}", lock.display()))
            }
        }
        if started.elapsed() > deadline {
            bail!(
                "{}: another host holds the init lock but never wrote a \
                 manifest (waited {:.1?}); remove {} if the initialising \
                 host is gone",
                dir.display(),
                started.elapsed(),
                lock.display()
            );
        }
        std::thread::sleep(options.poll);
    }
}

/// The per-level barrier: announce this host finished, wait until the
/// level is durably committed — by us if elected (or as a fallback when
/// the elected committer goes silent), by someone else otherwise.
/// Returns `true` iff *this* host performed the commit (the committer
/// also prunes and cleans the previous level).
pub fn barrier_commit(
    run: &mut ShardRun,
    ledger: &ClaimLedger,
    spec: &ShardSpec,
    k: usize,
    options: &ClusterOptions,
) -> Result<bool> {
    // an already-committed level needs no announcement — and a laggard's
    // late finish marker would recreate a ledger file that
    // `cleanup_level` (run when the *successor* committed) has already
    // swept, leaving a permanent stray on the shared mount
    if let Ok(disk) = ShardRun::open(run.dir()) {
        if disk.completed.is_some_and(|c| c >= k) {
            run.completed = disk.completed;
            return Ok(false);
        }
    }
    ledger.announce_finished(k)?;
    let waited = Instant::now();
    let mut first_err: Option<Instant> = None;
    let mut commit_err: Option<Instant> = None;
    loop {
        // 1. someone (possibly us, on a previous iteration's race loss)
        //    already committed this level — or raced past it
        match ShardRun::open(run.dir()) {
            Ok(disk) => {
                first_err = None;
                if disk.completed.is_some_and(|c| c >= k) {
                    run.completed = disk.completed;
                    return Ok(false);
                }
            }
            Err(e) => {
                // transient reads mid-rename are fine; persistent
                // unreadability is not
                let since = *first_err.get_or_insert_with(Instant::now);
                if since.elapsed() > options.stale_after() {
                    bail!(
                        "cluster barrier at level {k}: manifest unreadable \
                         for {:.1?}: {e:#}",
                        since.elapsed()
                    );
                }
            }
        }
        // 2. all shards done → elect the committer (lowest announced id;
        //    fall back to anyone if the elected host goes silent)
        if ledger.all_done(spec, k) {
            let elected = ledger
                .lowest_finisher(k)?
                .is_none_or(|low| low == ledger.host());
            if elected || waited.elapsed() > options.stale_after() {
                match commit_checked(run, k) {
                    Ok(did_commit) => return Ok(did_commit),
                    // the committer's own reload/rewrite can hit the same
                    // transient mid-rename window as the read loop above
                    // (another host's benign concurrent commit); retry
                    // with a bounded grace window of its own
                    Err(e) => {
                        let since = *commit_err.get_or_insert_with(Instant::now);
                        if since.elapsed() > options.stale_after() {
                            return Err(e);
                        }
                    }
                }
            }
        }
        std::thread::sleep(options.poll);
    }
}

/// Reload-check-commit: tolerate the benign "someone committed first"
/// race (returns `false`), reject genuinely out-of-order commits.
///
/// Also the rollback repair point: two hosts may commit concurrently by
/// design, and a committer that stalls between its manifest *read* and
/// its *rename* can land an old `levels_complete` over a newer one.
/// Levels this host has itself observed as committed are authoritative
/// the other way — the manifest is monotonic — so on evidence of a
/// regression we first restore our known state (atomic rewrite) instead
/// of adopting the rollback, which would wedge every later barrier on
/// the ordering check.
fn commit_checked(run: &mut ShardRun, k: usize) -> Result<bool> {
    let disk = ShardRun::open(run.dir())?;
    let effective = match (run.completed, disk.completed) {
        (Some(local), d) if d.is_none_or(|c| c < local) => {
            run.rewrite_manifest()?;
            Some(local)
        }
        (_, d) => d,
    };
    if effective.is_some_and(|c| c >= k) {
        run.completed = effective;
        return Ok(false);
    }
    let expect = effective.map_or(0, |c| c + 1);
    if expect != k {
        bail!(
            "cluster barrier out of order: disk shows levels_complete = \
             {:?} but this host tried to commit level {k}",
            effective
        );
    }
    run.completed = effective;
    run.commit_level(k)?;
    Ok(true)
}

/// Best-effort removal of a committed level's ledger files — claims
/// (including `.stale-*` steal remnants), done markers, finish markers —
/// and any staged shard strays a zombie writer left behind. With
/// `prune_frontier` the sweep also removes canonical `.bps`/`.qr` files
/// of the level: a very late zombie publish can *resurrect* frontier
/// files that [`ShardRun::prune_level`] already deleted, and this second
/// sweep (which runs one level later, when `k`'s successor commits — by
/// which point nobody reads `k`'s frontier) reclaims them. Pass `false`
/// for the final level, whose `.qr` record carries the run's score.
/// `.sink` files are never touched (reconstruction needs every level's).
/// Safe to run while laggards are still in the level's barrier: they
/// exit via the manifest check, which precedes every ledger read.
pub fn cleanup_level(dir: &Path, k: usize, prune_frontier: bool) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let claim = format!("claim-{k:02}-");
    let done = format!("done-{k:02}-");
    let finish = format!("finish-{k:02}-");
    let level = format!("level_{k:02}_");
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else {
            continue;
        };
        let staged_stray = name.starts_with(&level) && name.contains(".host-");
        let resurrected = prune_frontier
            && name.starts_with(&level)
            && (name.ends_with(".bps") || name.ends_with(".qr"));
        if name.starts_with(&claim)
            || name.starts_with(&done)
            || name.starts_with(&finish)
            || staged_stray
            || resurrected
        {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::SystemTime;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bnsl_cluster_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn ledger(dir: &Path, host: usize) -> ClaimLedger {
        ClaimLedger::new(dir, host, Duration::from_secs(2))
    }

    fn backdate(path: &Path, secs_ago: u64) {
        let file = File::options().write(true).open(path).unwrap();
        file.set_modified(SystemTime::now() - Duration::from_secs(secs_ago))
            .unwrap();
    }

    #[test]
    fn concurrent_claims_have_exactly_one_winner() {
        let dir = tmpdir("race");
        let won: Vec<bool> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|host| {
                    let dir = &dir;
                    scope.spawn(move || {
                        let ledger = ledger(dir, host);
                        matches!(ledger.try_claim(3, 1).unwrap(), ClaimState::Claimed(_))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            won.iter().filter(|&&w| w).count(),
            1,
            "exactly one of 8 contenders claims the shard: {won:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_claims_are_busy_stale_claims_are_stolen() {
        let dir = tmpdir("stale");
        let a = ledger(&dir, 0);
        let b = ledger(&dir, 1);
        let claim = match a.try_claim(5, 2).unwrap() {
            ClaimState::Claimed(c) => c,
            other => panic!("expected a claim, got {other:?}"),
        };
        // a live claim is not stealable, whatever B's own cadence is
        assert!(matches!(b.try_claim(5, 2).unwrap(), ClaimState::Busy));
        // a dead host's claim (mtime an hour old ≫ 4 × 2 s) is stolen…
        backdate(&claim.path, 3600);
        let stolen = match b.try_claim(5, 2).unwrap() {
            ClaimState::Claimed(c) => c,
            other => panic!("expected the steal to win, got {other:?}"),
        };
        // …and the zombie's heartbeat neither re-creates nor overwrites
        // the stolen claim: it is a pure mtime touch, so B's claim file
        // keeps recording B
        let mut zombie = claim;
        zombie.last_beat = Instant::now() - Duration::from_secs(60);
        zombie.heartbeat_if_due(&a);
        let text = std::fs::read_to_string(dir.join("claim-05-0002.json")).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("host").and_then(Json::as_u64), Some(1), "{text}");
        assert!(matches!(a.try_claim(5, 2).unwrap(), ClaimState::Busy));
        // the zombie's release is likewise ownership-gated: B's live
        // claim survives it
        a.release(&zombie);
        assert!(matches!(a.try_claim(5, 2).unwrap(), ClaimState::Busy));
        // done marker retires the shard for everyone
        b.mark_done(&stolen, 10, 120).unwrap();
        assert!(matches!(a.try_claim(5, 2).unwrap(), ClaimState::Done));
        assert!(matches!(b.try_claim(5, 2).unwrap(), ClaimState::Done));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn done_markers_and_release_drive_claim_states() {
        let dir = tmpdir("done");
        let a = ledger(&dir, 0);
        let claim = match a.try_claim(2, 0).unwrap() {
            ClaimState::Claimed(c) => c,
            other => panic!("{other:?}"),
        };
        // releasing re-opens the shard
        a.release(&claim);
        let claim = match a.try_claim(2, 0).unwrap() {
            ClaimState::Claimed(c) => c,
            other => panic!("release did not free the shard: {other:?}"),
        };
        a.mark_done(&claim, 4, 99).unwrap();
        assert!(matches!(a.try_claim(2, 0).unwrap(), ClaimState::Done));
        // the done marker is valid JSON naming the shard
        let text = std::fs::read_to_string(dir.join("done-02-0000.json")).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("entries").and_then(Json::as_u64), Some(4));
        assert_eq!(doc.get("host").and_then(Json::as_u64), Some(0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn all_done_ignores_empty_shards() {
        let dir = tmpdir("alldone");
        let a = ledger(&dir, 0);
        // 3 ranks across 4 shards: shard 3 is empty
        let spec = ShardSpec::new(3, 4);
        assert!(!a.all_done(&spec, 1));
        for s in 0..3 {
            let claim = match a.try_claim(1, s).unwrap() {
                ClaimState::Claimed(c) => c,
                other => panic!("{other:?}"),
            };
            a.mark_done(&claim, 1, 1).unwrap();
        }
        assert!(a.all_done(&spec, 1), "empty shard 3 needs no marker");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn election_picks_the_lowest_announced_host() {
        let dir = tmpdir("elect");
        let high = ledger(&dir, 7);
        assert_eq!(high.lowest_finisher(4).unwrap(), None);
        high.announce_finished(4).unwrap();
        assert_eq!(high.lowest_finisher(4).unwrap(), Some(7));
        ledger(&dir, 3).announce_finished(4).unwrap();
        ledger(&dir, 12).announce_finished(4).unwrap();
        assert_eq!(high.lowest_finisher(4).unwrap(), Some(3));
        // markers are level-scoped
        assert_eq!(high.lowest_finisher(5).unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn double_commit_is_rejected_and_commit_checked_tolerates_races() {
        let dir = tmpdir("commit");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut a = ShardRun::open_or_create(&opts, 8, 40, 4, "Jeffreys", "aa").unwrap();
        // A commits level 0; B (reading the committed state) has its raw
        // double commit rejected…
        a.commit_level(0).unwrap();
        let mut b = ShardRun::open(&dir).unwrap();
        let err = b.commit_level(0).unwrap_err().to_string();
        assert!(err.contains("already committed"), "{err}");
        // …but the barrier's reload-check-commit treats it as the benign
        // race it is
        let mut b = ShardRun::open(&dir).unwrap();
        assert!(!commit_checked(&mut b, 0).unwrap());
        assert_eq!(b.completed, Some(0));
        // and a genuinely out-of-order commit is still an error
        let err = commit_checked(&mut b, 5).unwrap_err().to_string();
        assert!(err.contains("out of order"), "{err}");
        // the in-order next level goes through
        assert!(commit_checked(&mut b, 1).unwrap());
        assert_eq!(committed_level(&dir), Some(1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn commit_checked_repairs_a_regressed_manifest_instead_of_wedging() {
        let dir = tmpdir("repair");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut run = ShardRun::open_or_create(&opts, 8, 40, 4, "Jeffreys", "bb").unwrap();
        run.commit_level(0).unwrap();
        run.commit_level(1).unwrap();
        // simulate a stalled committer's late rename landing an OLD
        // manifest over the new one: levels_complete rolls back 1 → 0
        let manifest = dir.join("manifest.json");
        let rolled = std::fs::read_to_string(&manifest)
            .unwrap()
            .replace("\"levels_complete\": 1", "\"levels_complete\": 0");
        std::fs::write(&manifest, rolled).unwrap();
        assert_eq!(committed_level(&dir), Some(0), "regression in place");
        // a host that observed level 1 commit repairs forward and
        // commits level 2 instead of bailing 'out of order'
        assert!(commit_checked(&mut run, 2).unwrap());
        assert_eq!(committed_level(&dir), Some(2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cleanup_removes_ledger_files_but_not_shard_data() {
        let dir = tmpdir("cleanup");
        let a = ledger(&dir, 0);
        let claim = match a.try_claim(3, 0).unwrap() {
            ClaimState::Claimed(c) => c,
            other => panic!("{other:?}"),
        };
        a.mark_done(&claim, 1, 1).unwrap();
        a.announce_finished(3).unwrap();
        std::fs::write(dir.join("claim-03-0001.json"), "{}").unwrap();
        std::fs::write(dir.join("claim-03-0002.json.stale-1-99"), "{}").unwrap();
        std::fs::write(dir.join("level_03_shard_0000.sink"), "data").unwrap();
        std::fs::write(dir.join("level_03_shard_0001.qr.host-0009-1-7"), "stray").unwrap();
        // a zombie's late publish resurrected a pruned frontier file
        std::fs::write(dir.join("level_03_shard_0001.qr"), "resurrected").unwrap();
        std::fs::write(dir.join("done-04-0000.json"), "{}").unwrap();
        cleanup_level(&dir, 3, true);
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert!(
            names.contains(&"level_03_shard_0000.sink".to_string()),
            "sink data survives cleanup: {names:?}"
        );
        assert!(
            names.contains(&"done-04-0000.json".to_string()),
            "other levels' ledgers survive: {names:?}"
        );
        for gone in [
            "claim-03-0001.json",
            "claim-03-0002.json.stale-1-99",
            "done-03-0000.json",
            "finish-03-host-0000.json",
            "level_03_shard_0001.qr.host-0009-1-7",
            "level_03_shard_0001.qr",
        ] {
            assert!(!names.contains(&gone.to_string()), "{gone} not cleaned: {names:?}");
        }
        // without prune_frontier (the final level), .qr files survive
        std::fs::write(dir.join("level_05_shard_0000.qr"), "final score").unwrap();
        std::fs::write(dir.join("done-05-0000.json"), "{}").unwrap();
        cleanup_level(&dir, 5, false);
        assert!(dir.join("level_05_shard_0000.qr").exists());
        assert!(!dir.join("done-05-0000.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_or_create_shared_initialises_exactly_once_across_hosts() {
        let dir = tmpdir("init");
        let mk = |host: usize| ClusterOptions {
            shard: ShardOptions {
                shards: 2,
                dir: dir.clone(),
                hosts: 4,
                ..Default::default()
            },
            host_id: host,
            heartbeat: Duration::from_millis(200),
            poll: Duration::from_millis(2),
        };
        let runs: Vec<ShardRun> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|host| {
                    let mk = &mk;
                    scope.spawn(move || {
                        open_or_create_shared(&mk(host), 10, 50, 4, "Jeffreys", "f00f").unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for run in &runs {
            assert_eq!(run.p, 10);
            assert_eq!(run.shards, 2);
            assert_eq!(run.completed, None);
        }
        assert!(!dir.join("cluster-init.lock").exists(), "lock released");
        // a stale abandoned lock does not wedge a later initialisation,
        // and a crashed committer's old manifest temp is swept on entry
        let dir2 = tmpdir("init_stale");
        std::fs::write(dir2.join("cluster-init.lock"), "{}").unwrap();
        backdate(&dir2.join("cluster-init.lock"), 3600);
        std::fs::write(dir2.join("manifest.json.tmp.99.0"), "{}").unwrap();
        backdate(&dir2.join("manifest.json.tmp.99.0"), 3600);
        let opts = ClusterOptions {
            shard: ShardOptions {
                shards: 2,
                dir: dir2.clone(),
                ..Default::default()
            },
            heartbeat: Duration::from_millis(100),
            poll: Duration::from_millis(2),
            ..Default::default()
        };
        let run = open_or_create_shared(&opts, 6, 20, 4, "Bic", "0ff0").unwrap();
        assert_eq!(run.p, 6);
        assert!(
            !dir2.join("manifest.json.tmp.99.0").exists(),
            "crashed committer's temp swept"
        );
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }
}
