//! Multi-host cluster coordination over shared storage — the claim
//! ledger behind [`crate::solver::solve_clustered`].
//!
//! The sharded coordinator ([`crate::coordinator::shard`]) already made
//! the frontier host-agnostic: every level is a set of shard streams
//! plus one atomically-committed `manifest.json`. This module adds the
//! piece that lets **N independent `bnsl` processes — on one machine or
//! many, sharing only a storage root** — cooperate on one solve. Every
//! durable step goes through the pluggable
//! [`crate::coordinator::storage::StorageBackend`], so the same
//! protocol runs on a POSIX mount (`O_EXCL`, rename, mtime) and on an
//! S3-style object store (conditional PUT, server-side copy, versioned
//! heartbeat metadata):
//!
//! * **Claims.** A host takes a (level, shard) pair by creating
//!   `claim-<level>-<shard>.json` with the backend's atomic
//!   create-if-absent — `O_CREAT|O_EXCL` on POSIX, a conditional PUT
//!   (`If-None-Match: *`) on an object store. The claim records host
//!   id, pid and the owner's heartbeat cadence.
//! * **Heartbeats.** While computing, the owner refreshes its claim's
//!   liveness stamp (mtime on POSIX, a versioned heartbeat metadata key
//!   on an object store) at least twice per heartbeat interval. A claim
//!   whose stamp is older than `4 ×` its recorded cadence is *stale*:
//!   the owner is presumed dead and the work is re-runnable.
//! * **Reclaim.** Stealing a stale claim is a contended remove — exactly
//!   one host's remove succeeds — after which the winner re-creates the
//!   claim as its own. A SIGKILLed host's unfinished shards are
//!   therefore re-run, not lost; its *finished* shards survive via
//!   durably-published `done-<level>-<shard>.json` markers and are never
//!   recomputed.
//! * **Zombie safety.** A host that lost its claim but keeps computing
//!   writes only to staged streams
//!   ([`crate::coordinator::shard::ShardWriterSet::create_staged`]) and
//!   publishes atomically (rename on POSIX, completed-upload + copy on
//!   an object store). Because every execution mode of the sweep is
//!   bit-identical (the repo's core invariant), a zombie's publish
//!   writes the same bytes the reclaimer produced — a stale writer can
//!   overwrite, but never corrupt.
//! * **Barrier + election.** A level commits when every non-empty shard
//!   has a done marker. Each host that observes this writes
//!   `finish-<level>-host-<id>.json`; the **lowest host id among the
//!   finish markers present** performs the existing durable
//!   [`crate::coordinator::shard::ShardRun::commit_level`] rewrite.
//!   If the elected committer dies first, any host commits after a
//!   stale-interval fallback; the benign double-commit race writes
//!   identical manifests through the backend's atomic publish, and
//!   genuinely out-of-order commits are rejected by `commit_level`
//!   itself.
//! * **Resume.** The manifest stays the durability boundary: any
//!   surviving or restarted host re-enters at `levels_complete + 1`
//!   and the ledger replays only the in-flight level's missing shards —
//!   `--resume` semantics compose unchanged.
//!
//! Listings may lag on object backends (and the
//! [`crate::coordinator::storage::ObjectBackend`] injects exactly that
//! fault), so the protocol treats listings as hints: authoritative
//! decisions read the manifest or probe individual keys, and every
//! cleanup delete is idempotent — a ghost entry can cost a wasted
//! delete, never resurrect state.
//!
//! File-level schemas live in
//! [`docs/FORMATS.md`](https://github.com/paper-repo-growth/bnsl/blob/main/docs/FORMATS.md)
//! (in-tree: `docs/FORMATS.md`); the protocol walkthrough and the
//! per-step backend-semantics table are in
//! [`docs/ARCHITECTURE.md`](https://github.com/paper-repo-growth/bnsl/blob/main/docs/ARCHITECTURE.md)
//! (in-tree: `docs/ARCHITECTURE.md`).

use super::shard::{ShardRun, ShardSpec};
use super::storage::{make_backend, CreateOutcome, KeyAge, SharedBackend};
use crate::solver::PruneStamp;
use crate::telemetry::{self, trace};
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Per-process sequence for stage tags: same-process workers (and a
/// worker re-claiming its stalled sibling's shard) must never share a
/// staged stream name, or one writer's create would truncate the
/// other's in-flight stream.
static STAGE_SEQ: AtomicU64 = AtomicU64::new(0);

/// A claim is stale once its liveness stamp is older than this many
/// heartbeat intervals — generous enough to ride out scheduler hiccups,
/// small enough that a SIGKILLed host's shard is re-run promptly.
pub const STALE_FACTOR: u32 = 4;

/// Tuning for one cluster host (see [`crate::solver::solve_clustered`]).
#[derive(Clone, Debug)]
pub struct ClusterOptions {
    /// The underlying sharded-run options (shard count, worker pool,
    /// batch size, run directory, storage backend, checkpointing).
    pub shard: super::shard::ShardOptions,
    /// This host's id — ties are broken and the committer elected by
    /// *lowest id*, so ids should be distinct across live hosts (a
    /// restarted host reuses its id safely). The declared pool size
    /// lives in [`super::shard::ShardOptions::hosts`] (one source of
    /// truth — it is what the manifest records).
    pub host_id: usize,
    /// Claim heartbeat cadence. Claims older than
    /// [`STALE_FACTOR`]`× heartbeat` are reclaimable, so this bounds how
    /// long a dead host's shard stays orphaned. Must exceed the storage
    /// backend's liveness-stamp granularity by a comfortable margin.
    pub heartbeat: Duration,
    /// Sleep between ledger polls while waiting on other hosts.
    pub poll: Duration,
}

impl Default for ClusterOptions {
    fn default() -> ClusterOptions {
        ClusterOptions {
            shard: super::shard::ShardOptions::default(),
            host_id: 0,
            heartbeat: Duration::from_secs(30),
            poll: Duration::from_millis(500),
        }
    }
}

impl ClusterOptions {
    /// Age past which a claim (or the init lock, or a silent committer)
    /// is treated as dead.
    pub fn stale_after(&self) -> Duration {
        self.heartbeat * STALE_FACTOR
    }
}

/// Outcome of one [`ClaimLedger::try_claim`] attempt.
#[derive(Debug)]
pub enum ClaimState {
    /// This host now owns the shard and must compute + publish it.
    Claimed(Claim),
    /// The shard's done marker exists — nothing to do.
    Done,
    /// Another host holds a live claim; re-poll later.
    Busy,
}

/// A live claim on one (level, shard) pair — the ownership token a
/// worker heartbeats while computing and redeems with
/// [`ClaimLedger::mark_done`].
#[derive(Debug)]
pub struct Claim {
    pub level: usize,
    pub shard: usize,
    key: String,
    last_beat: Instant,
}

impl Claim {
    /// Refresh the claim's liveness stamp if half a heartbeat has
    /// elapsed (cheap no-op otherwise — callers tick this once per
    /// batch). The refresh is a pure liveness touch — never a content
    /// write and never a create — so there is no window in which a
    /// waking zombie could truncate or overwrite a claim a reclaimer
    /// now owns: at worst it keeps the reclaimer's live claim fresh
    /// (which the reclaimer's own heartbeat does anyway), and a deleted
    /// claim is never resurrected.
    pub fn heartbeat_if_due(&mut self, ledger: &ClaimLedger) {
        if self.last_beat.elapsed() * 2 < ledger.heartbeat {
            return;
        }
        self.last_beat = Instant::now();
        ledger.store.touch(&self.key);
        telemetry::cluster_heartbeats().inc();
    }
}

/// The per-run claim ledger: one host's handle on the shared
/// claim / done / finish keys of an in-flight level.
pub struct ClaimLedger {
    store: SharedBackend,
    host: usize,
    heartbeat: Duration,
    /// Stage-tag prefix for this process's shard writers:
    /// `host-<id>-<pid>`, unique across live processes even when a host
    /// id is reused after a restart.
    stage_prefix: String,
}

impl ClaimLedger {
    pub fn new(store: SharedBackend, host: usize, heartbeat: Duration) -> ClaimLedger {
        ClaimLedger {
            store,
            host,
            heartbeat,
            stage_prefix: format!("host-{host:04}-{}", std::process::id()),
        }
    }

    pub fn host(&self) -> usize {
        self.host
    }

    /// A fresh writer-unique suffix for one claimed shard's staged
    /// streams: `host-<id>-<pid>-<seq>`. The sequence is what keeps a
    /// *same-process* stale-claim steal safe — without it, a sibling
    /// worker reclaiming a stalled worker's shard would truncate the
    /// very staged stream the stalled writer still holds open, and the
    /// interleaved streams could get published.
    pub fn fresh_stage_tag(&self) -> String {
        format!(
            "{}-{}",
            self.stage_prefix,
            STAGE_SEQ.fetch_add(1, Ordering::Relaxed)
        )
    }

    /// Trace fields for claim/steal events (built only when tracing).
    fn claim_fields(&self, k: usize, s: usize) -> Json {
        Json::obj()
            .set("level", k)
            .set("shard", s)
            .set("host", self.host)
    }

    fn claim_key(&self, k: usize, s: usize) -> String {
        format!("claim-{k:02}-{s:04}.json")
    }

    fn done_key(&self, k: usize, s: usize) -> String {
        format!("done-{k:02}-{s:04}.json")
    }

    fn finish_key(&self, k: usize, host: usize) -> String {
        format!("finish-{k:02}-host-{host:04}.json")
    }

    /// Attempt to take (level `k`, shard `s`): done markers win, then an
    /// atomic create-if-absent claim, then a stale-claim steal; anything
    /// else is [`ClaimState::Busy`].
    pub fn try_claim(&self, k: usize, s: usize) -> Result<ClaimState> {
        if self.store.exists(&self.done_key(k, s))? {
            return Ok(ClaimState::Done);
        }
        let key = self.claim_key(k, s);
        if let Some(claim) = self.create_claim(&key, k, s)? {
            telemetry::cluster_claims().inc();
            if trace::enabled() {
                trace::event("claim", self.claim_fields(k, s));
            }
            return Ok(ClaimState::Claimed(claim));
        }
        if self.claim_is_stale(&key) {
            // steal: of all contenders observing the same stale claim,
            // exactly one contended remove succeeds
            let tag = format!("stale-{}-{}", self.host, std::process::id());
            if self.store.remove_contended(&key, &tag)? {
                if let Some(claim) = self.create_claim(&key, k, s)? {
                    telemetry::cluster_claims().inc();
                    telemetry::cluster_steals().inc();
                    if trace::enabled() {
                        trace::event("claim_steal", self.claim_fields(k, s));
                    }
                    return Ok(ClaimState::Claimed(claim));
                }
            }
        }
        Ok(ClaimState::Busy)
    }

    /// `Some(claim)` iff this host's create-if-absent won.
    fn create_claim(&self, key: &str, k: usize, s: usize) -> Result<Option<Claim>> {
        let body = Json::obj()
            .set("format", 1u64)
            .set("level", k)
            .set("shard", s)
            .set("host", self.host)
            .set("pid", std::process::id())
            .set("heartbeat_secs", self.heartbeat.as_secs_f64())
            .to_pretty();
        match self.store.create_exclusive(key, body.as_bytes())? {
            CreateOutcome::Created => Ok(Some(Claim {
                level: k,
                shard: s,
                key: key.to_string(),
                last_beat: Instant::now(),
            })),
            CreateOutcome::AlreadyExists => Ok(None),
        }
    }

    /// A claim is stale when its liveness stamp is older than
    /// [`STALE_FACTOR`] × the cadence *the claim itself recorded*
    /// (falling back to ours for unreadable claims), so hosts with
    /// different `--heartbeat-secs` judge each other by the owner's
    /// contract, not their own.
    ///
    /// Clock skew: liveness stamps come from whatever clock the backend
    /// records (an NFS server's mtime, an object heartbeat's wall
    /// clock), ages from the observer. A small future-dated stamp is
    /// tolerated as fresh, but one further in the future than the stale
    /// window itself is treated as *stale-eligible* — a spurious steal
    /// merely duplicates deterministic work (zombie-safe), whereas
    /// "future means fresh forever" would let an absurdly skewed stamp
    /// orphan a dead host's shard indefinitely.
    fn claim_is_stale(&self, key: &str) -> bool {
        let Some(age) = self.store.liveness_age(key) else {
            return false;
        };
        let cadence = self
            .store
            .read_doc(key)
            .ok()
            .flatten()
            .and_then(|bytes| String::from_utf8(bytes).ok())
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|doc| doc.get("heartbeat_secs").and_then(Json::as_f64))
            .filter(|h| h.is_finite() && *h > 0.0)
            // clamp before Duration::from_secs_f64, which panics on
            // out-of-range values — a foreign/corrupt-but-parsable
            // cadence must not be able to crash every scanning host
            .map_or(self.heartbeat, |h| {
                Duration::from_secs_f64(h.min(86_400.0))
            });
        let window = cadence * STALE_FACTOR;
        match age {
            KeyAge::Past(age) => age > window,
            KeyAge::Future(skew) => skew > window,
        }
    }

    /// Durably record a computed shard: the done marker is published
    /// atomically *after* the shard streams themselves were made durable
    /// and published, so a marker never vouches for bytes the backend
    /// could lose. The claim is then released.
    pub fn mark_done(&self, claim: &Claim, entries: u64, bytes: u64) -> Result<()> {
        let doc = Json::obj()
            .set("level", claim.level)
            .set("shard", claim.shard)
            .set("host", self.host)
            .set("entries", entries)
            .set("bytes", bytes);
        self.store.publish_doc(
            &self.done_key(claim.level, claim.shard),
            doc.to_pretty().as_bytes(),
        )?;
        self.release(claim);
        telemetry::cluster_shards_done().inc();
        if trace::enabled() {
            trace::event(
                "shard_done",
                self.claim_fields(claim.level, claim.shard)
                    .set("entries", entries)
                    .set("bytes", bytes),
            );
        }
        Ok(())
    }

    /// Does the claim at `key` still record this host and process?
    /// Checked before deleting, so a zombie whose claim was stolen
    /// cannot delete the reclaimer's live claim out from under it.
    fn owns_claim(&self, key: &str) -> bool {
        self.store
            .read_doc(key)
            .ok()
            .flatten()
            .and_then(|bytes| String::from_utf8(bytes).ok())
            .and_then(|text| Json::parse(&text).ok())
            .is_some_and(|doc| {
                doc.get("host").and_then(Json::as_u64) == Some(self.host as u64)
                    && doc.get("pid").and_then(Json::as_u64)
                        == Some(u64::from(std::process::id()))
            })
    }

    /// Release an unredeemed claim (abandoning the shard, e.g. when the
    /// level turned out to be superseded) — but only if it is still
    /// ours; a stolen claim belongs to its reclaimer now.
    pub fn release(&self, claim: &Claim) {
        if self.owns_claim(&claim.key) {
            let _ = self.store.delete(&claim.key);
        }
    }

    /// Every non-empty shard of level `k` has a done marker. Probe
    /// errors read as "not done" — the barrier re-polls, so a transient
    /// storage hiccup delays the commit instead of crashing it.
    pub fn all_done(&self, spec: &ShardSpec, k: usize) -> bool {
        (0..spec.shards).all(|s| {
            spec.entries(s) == 0 || self.store.exists(&self.done_key(k, s)).unwrap_or(false)
        })
    }

    /// Announce this host finished its share of level `k` (idempotent).
    pub fn announce_finished(&self, k: usize) -> Result<()> {
        let key = self.finish_key(k, self.host);
        let doc = Json::obj()
            .set("level", k)
            .set("host", self.host)
            .set("pid", std::process::id());
        self.store.put_doc(&key, doc.to_pretty().as_bytes())
    }

    /// Lowest host id among level `k`'s finish markers (`None` before
    /// any host announced) — the committer election. Reads a listing,
    /// which may lag on object backends; that is safe because the
    /// election only *selects* a committer among hosts that all observed
    /// the same done markers, and the manifest check preceding every
    /// ledger read is what decides whether the level is already over.
    pub fn lowest_finisher(&self, k: usize) -> Result<Option<usize>> {
        let prefix = format!("finish-{k:02}-host-");
        let mut lowest: Option<usize> = None;
        for name in self.store.list(&prefix)? {
            let Some(rest) = name.strip_prefix(&prefix) else {
                continue;
            };
            let Some(id) = rest
                .strip_suffix(".json")
                .and_then(|digits| digits.parse::<usize>().ok())
            else {
                continue;
            };
            lowest = Some(lowest.map_or(id, |low| low.min(id)));
        }
        Ok(lowest)
    }
}

/// `levels_complete` as currently in storage: `Some(-1)` for a manifest
/// with nothing committed, `None` when the manifest is unreadable
/// (transient mid-publish reads included).
pub fn committed_level(store: &SharedBackend) -> Option<i64> {
    let run = ShardRun::open_on(store.clone()).ok()?;
    Some(run.completed.map_or(-1, |c| c as i64))
}

/// [`committed_level`], but riding out transiently unreadable manifests
/// (a concurrent commit's publish, a read-after-write lag) for up to
/// `grace`. For one-shot decisions — "is this failure survivable because
/// the level was superseded?" — where a single unlucky read must not
/// turn a rejoin into a fatal error. Returns `None` only if the manifest
/// stayed unreadable through the whole window.
pub fn committed_level_patient(
    store: &SharedBackend,
    grace: Duration,
    poll: Duration,
) -> Option<i64> {
    let start = Instant::now();
    loop {
        if let Some(c) = committed_level(store) {
            return Some(c);
        }
        if start.elapsed() > grace {
            return None;
        }
        std::thread::sleep(poll);
    }
}

/// Open the shared run, creating it exactly once across the cluster: the
/// first host to win the create-if-absent `cluster-init.lock` writes the
/// manifest; everyone else waits for it to appear and then takes the
/// ordinary validate-and-resume path. A lock whose holder died (stale
/// liveness stamp) is removed and re-contested.
#[allow(clippy::too_many_arguments)]
pub fn open_or_create_shared(
    options: &ClusterOptions,
    p: usize,
    n: usize,
    mask_bytes: usize,
    score: &str,
    fingerprint: &str,
    prune: Option<PruneStamp>,
) -> Result<ShardRun> {
    let store = make_backend(options.shard.backend, &options.shard.dir)?;
    store.ensure_root()?;
    // crashed publishers/uploaders leave internal temp strays; sweep old
    // ones on the way in (never young ones — a live write's temp exists
    // only for milliseconds, so the stale window is a generous bound)
    store.sweep_internal(options.stale_after());
    let lock = "cluster-init.lock";
    let started = Instant::now();
    // ample for "another host is writing a two-kilobyte manifest"
    let deadline = options.stale_after() * 4 + Duration::from_secs(10);
    loop {
        if store.exists("manifest.json")? {
            return ShardRun::open_or_create_on(
                store,
                &options.shard,
                p,
                n,
                mask_bytes,
                score,
                fingerprint,
                prune,
            );
        }
        let lock_body = Json::obj()
            .set("host", options.host_id)
            .set("pid", std::process::id())
            .to_pretty();
        match store.create_exclusive(lock, lock_body.as_bytes())? {
            CreateOutcome::Created => {
                let run = ShardRun::open_or_create_on(
                    store.clone(),
                    &options.shard,
                    p,
                    n,
                    mask_bytes,
                    score,
                    fingerprint,
                    prune,
                );
                let _ = store.delete(lock);
                return run;
            }
            CreateOutcome::AlreadyExists => {
                // another host is initialising; steal only a dead lock,
                // and steal through the contended remove so exactly one
                // contender wins — a blind delete keyed on an earlier
                // probe could remove a *fresh* lock the winner just
                // re-created, letting two hosts initialise (and one
                // later regress) the manifest
                let age = match store.liveness_age(lock) {
                    Some(KeyAge::Past(age)) => age,
                    _ => Duration::ZERO,
                };
                if age > options.stale_after() {
                    let tag = format!("stale-{}-{}", options.host_id, std::process::id());
                    let _ = store.remove_contended(lock, &tag)?;
                    continue;
                }
            }
        }
        if started.elapsed() > deadline {
            bail!(
                "{}: another host holds the init lock but never wrote a \
                 manifest (waited {:.1?}); remove {}/cluster-init.lock if \
                 the initialising host is gone",
                store.root(),
                started.elapsed(),
                store.root()
            );
        }
        std::thread::sleep(options.poll);
    }
}

/// The per-level barrier: announce this host finished, wait until the
/// level is durably committed — by us if elected (or as a fallback when
/// the elected committer goes silent), by someone else otherwise.
/// Returns `true` iff *this* host performed the commit (the committer
/// also prunes and cleans the previous level).
pub fn barrier_commit(
    run: &mut ShardRun,
    ledger: &ClaimLedger,
    spec: &ShardSpec,
    k: usize,
    options: &ClusterOptions,
) -> Result<bool> {
    // an already-committed level needs no announcement — and a laggard's
    // late finish marker would recreate a ledger key that
    // `cleanup_level` (run when the *successor* committed) has already
    // swept, leaving a permanent stray in the shared root
    if let Ok(disk) = ShardRun::open_on(run.store().clone()) {
        if disk.completed.is_some_and(|c| c >= k) {
            run.completed = disk.completed;
            return Ok(false);
        }
    }
    ledger.announce_finished(k)?;
    let waited = Instant::now();
    let mut first_err: Option<Instant> = None;
    let mut commit_err: Option<Instant> = None;
    loop {
        // 1. someone (possibly us, on a previous iteration's race loss)
        //    already committed this level — or raced past it
        match ShardRun::open_on(run.store().clone()) {
            Ok(disk) => {
                first_err = None;
                if disk.completed.is_some_and(|c| c >= k) {
                    run.completed = disk.completed;
                    return Ok(false);
                }
            }
            Err(e) => {
                // transient reads mid-publish are fine; persistent
                // unreadability is not
                let since = *first_err.get_or_insert_with(Instant::now);
                if since.elapsed() > options.stale_after() {
                    bail!(
                        "cluster barrier at level {k}: manifest unreadable \
                         for {:.1?}: {e:#}",
                        since.elapsed()
                    );
                }
            }
        }
        // 2. all shards done → elect the committer (lowest announced id;
        //    fall back to anyone if the elected host goes silent)
        if ledger.all_done(spec, k) {
            let elected = ledger
                .lowest_finisher(k)?
                .is_none_or(|low| low == ledger.host());
            if elected || waited.elapsed() > options.stale_after() {
                match commit_checked(run, k) {
                    Ok(did_commit) => return Ok(did_commit),
                    // the committer's own reload/rewrite can hit the same
                    // transient mid-publish window as the read loop above
                    // (another host's benign concurrent commit); retry
                    // with a bounded grace window of its own
                    Err(e) => {
                        let since = *commit_err.get_or_insert_with(Instant::now);
                        if since.elapsed() > options.stale_after() {
                            return Err(e);
                        }
                    }
                }
            }
        }
        std::thread::sleep(options.poll);
    }
}

/// Reload-check-commit: tolerate the benign "someone committed first"
/// race (returns `false`), reject genuinely out-of-order commits.
///
/// Also the rollback repair point: two hosts may commit concurrently by
/// design, and a committer that stalls between its manifest *read* and
/// its *publish* can land an old `levels_complete` over a newer one.
/// Levels this host has itself observed as committed are authoritative
/// the other way — the manifest is monotonic — so on evidence of a
/// regression we first restore our known state (atomic rewrite) instead
/// of adopting the rollback, which would wedge every later barrier on
/// the ordering check.
fn commit_checked(run: &mut ShardRun, k: usize) -> Result<bool> {
    let disk = ShardRun::open_on(run.store().clone())?;
    let effective = match (run.completed, disk.completed) {
        (Some(local), d) if d.is_none_or(|c| c < local) => {
            run.rewrite_manifest()?;
            Some(local)
        }
        (_, d) => d,
    };
    if effective.is_some_and(|c| c >= k) {
        run.completed = effective;
        return Ok(false);
    }
    let expect = effective.map_or(0, |c| c + 1);
    if expect != k {
        bail!(
            "cluster barrier out of order: disk shows levels_complete = \
             {:?} but this host tried to commit level {k}",
            effective
        );
    }
    run.completed = effective;
    run.commit_level(k)?;
    telemetry::cluster_commits().inc();
    if trace::enabled() {
        trace::event("level_commit", Json::obj().set("level", k));
    }
    Ok(true)
}

/// Best-effort removal of a committed level's ledger keys — claims
/// (including `.stale-*` steal remnants), done markers, finish markers —
/// and any staged shard strays a zombie writer left behind. With
/// `prune_frontier` the sweep also removes canonical `.bps`/`.qr`
/// streams of the level: a very late zombie publish can *resurrect*
/// frontier data that [`ShardRun::prune_level`] already deleted, and
/// this second sweep (which runs one level later, when `k`'s successor
/// commits — by which point nobody reads `k`'s frontier) reclaims them.
/// Pass `false` for the final level, whose `.qr` record carries the
/// run's score. `.sink` streams are never touched (reconstruction needs
/// every level's). Safe to run while laggards are still in the level's
/// barrier: they exit via the manifest check, which precedes every
/// ledger read. Also safe against lagging (ghost-bearing) listings:
/// every delete here is idempotent, so a ghost entry costs one wasted
/// delete and resurrects nothing.
pub fn cleanup_level(store: &SharedBackend, k: usize, prune_frontier: bool) {
    let Ok(names) = store.list("") else {
        return;
    };
    let claim = format!("claim-{k:02}-");
    let done = format!("done-{k:02}-");
    let finish = format!("finish-{k:02}-");
    let level = format!("level_{k:02}_");
    for name in names {
        let staged_stray = name.starts_with(&level) && name.contains(".host-");
        let resurrected = prune_frontier
            && name.starts_with(&level)
            && (name.ends_with(".bps") || name.ends_with(".qr"));
        if name.starts_with(&claim)
            || name.starts_with(&done)
            || name.starts_with(&finish)
            || staged_stray
            || resurrected
        {
            let _ = store.delete(&name);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::ShardOptions;
    use crate::coordinator::storage::{ObjectBackend, ObjectFaults, PosixBackend};
    use std::path::{Path, PathBuf};
    use std::sync::Arc;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bnsl_cluster_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// One fresh store per backend kind, each over its own tmpdir — the
    /// ledger tests run the identical scenario on both.
    fn stores(tag: &str) -> Vec<(&'static str, SharedBackend, PathBuf)> {
        let posix_dir = tmpdir(&format!("{tag}_posix"));
        let object_dir = tmpdir(&format!("{tag}_object"));
        vec![
            (
                "posix",
                Arc::new(PosixBackend::new(&posix_dir)) as SharedBackend,
                posix_dir,
            ),
            (
                "object",
                Arc::new(ObjectBackend::with_faults(
                    &object_dir,
                    ObjectFaults::default(),
                )) as SharedBackend,
                object_dir,
            ),
        ]
    }

    fn ledger(store: &SharedBackend, host: usize) -> ClaimLedger {
        ClaimLedger::new(store.clone(), host, Duration::from_secs(2))
    }

    fn posix(dir: &Path) -> SharedBackend {
        Arc::new(PosixBackend::new(dir))
    }

    #[test]
    fn concurrent_claims_have_exactly_one_winner() {
        for (label, store, dir) in stores("race") {
            let won: Vec<bool> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..8)
                    .map(|host| {
                        let store = &store;
                        scope.spawn(move || {
                            let ledger = ledger(store, host);
                            matches!(
                                ledger.try_claim(3, 1).unwrap(),
                                ClaimState::Claimed(_)
                            )
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            assert_eq!(
                won.iter().filter(|&&w| w).count(),
                1,
                "{label}: exactly one of 8 contenders claims the shard: {won:?}"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    /// The reclaim path on both backends: a lost heartbeat makes the
    /// claim stealable by exactly one contender, the zombie's heartbeat
    /// and release cannot touch the reclaimer's claim, and the work is
    /// handed out exactly once per stale epoch (no double execution —
    /// after the steal the shard reads Busy, then Done).
    #[test]
    fn lost_heartbeat_triggers_reclaim_without_double_execution() {
        for (label, store, dir) in stores("stale") {
            let a = ledger(&store, 0);
            let b = ledger(&store, 1);
            let claim = match a.try_claim(5, 2).unwrap() {
                ClaimState::Claimed(c) => c,
                other => panic!("{label}: expected a claim, got {other:?}"),
            };
            // a live claim is not stealable, whatever B's own cadence is
            assert!(
                matches!(b.try_claim(5, 2).unwrap(), ClaimState::Busy),
                "{label}"
            );
            // a dead host's claim (stamp an hour old ≫ 4 × 2 s) is stolen…
            store.backdate("claim-05-0002.json", Duration::from_secs(3600));
            let stolen = match b.try_claim(5, 2).unwrap() {
                ClaimState::Claimed(c) => c,
                other => panic!("{label}: expected the steal to win, got {other:?}"),
            };
            // …and the zombie's heartbeat neither re-creates nor
            // overwrites the stolen claim: it is a pure liveness touch,
            // so the claim body keeps recording B
            let mut zombie = claim;
            zombie.last_beat = Instant::now() - Duration::from_secs(60);
            zombie.heartbeat_if_due(&a);
            let text = String::from_utf8(
                store.read_doc("claim-05-0002.json").unwrap().unwrap(),
            )
            .unwrap();
            let doc = Json::parse(&text).unwrap();
            assert_eq!(
                doc.get("host").and_then(Json::as_u64),
                Some(1),
                "{label}: {text}"
            );
            assert!(
                matches!(a.try_claim(5, 2).unwrap(), ClaimState::Busy),
                "{label}: the shard is not handed out twice"
            );
            // the zombie's release is likewise ownership-gated: B's live
            // claim survives it
            a.release(&zombie);
            assert!(
                matches!(a.try_claim(5, 2).unwrap(), ClaimState::Busy),
                "{label}"
            );
            // done marker retires the shard for everyone, recording the
            // reclaimer as the one host that executed it
            b.mark_done(&stolen, 10, 120).unwrap();
            assert!(matches!(a.try_claim(5, 2).unwrap(), ClaimState::Done), "{label}");
            assert!(matches!(b.try_claim(5, 2).unwrap(), ClaimState::Done), "{label}");
            let done = String::from_utf8(
                store.read_doc("done-05-0002.json").unwrap().unwrap(),
            )
            .unwrap();
            let doc = Json::parse(&done).unwrap();
            assert_eq!(doc.get("host").and_then(Json::as_u64), Some(1), "{label}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn done_markers_and_release_drive_claim_states() {
        for (label, store, dir) in stores("done") {
            let a = ledger(&store, 0);
            let claim = match a.try_claim(2, 0).unwrap() {
                ClaimState::Claimed(c) => c,
                other => panic!("{label}: {other:?}"),
            };
            // releasing re-opens the shard
            a.release(&claim);
            let claim = match a.try_claim(2, 0).unwrap() {
                ClaimState::Claimed(c) => c,
                other => panic!("{label}: release did not free the shard: {other:?}"),
            };
            a.mark_done(&claim, 4, 99).unwrap();
            assert!(matches!(a.try_claim(2, 0).unwrap(), ClaimState::Done), "{label}");
            // the done marker is valid JSON naming the shard
            let text = String::from_utf8(
                store.read_doc("done-02-0000.json").unwrap().unwrap(),
            )
            .unwrap();
            let doc = Json::parse(&text).unwrap();
            assert_eq!(doc.get("entries").and_then(Json::as_u64), Some(4), "{label}");
            assert_eq!(doc.get("host").and_then(Json::as_u64), Some(0), "{label}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn all_done_ignores_empty_shards() {
        for (label, store, dir) in stores("alldone") {
            let a = ledger(&store, 0);
            // 3 ranks across 4 shards: shard 3 is empty
            let spec = ShardSpec::new(3, 4);
            assert!(!a.all_done(&spec, 1), "{label}");
            for s in 0..3 {
                let claim = match a.try_claim(1, s).unwrap() {
                    ClaimState::Claimed(c) => c,
                    other => panic!("{label}: {other:?}"),
                };
                a.mark_done(&claim, 1, 1).unwrap();
            }
            assert!(a.all_done(&spec, 1), "{label}: empty shard 3 needs no marker");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn election_picks_the_lowest_announced_host() {
        for (label, store, dir) in stores("elect") {
            let high = ledger(&store, 7);
            assert_eq!(high.lowest_finisher(4).unwrap(), None, "{label}");
            high.announce_finished(4).unwrap();
            assert_eq!(high.lowest_finisher(4).unwrap(), Some(7), "{label}");
            ledger(&store, 3).announce_finished(4).unwrap();
            ledger(&store, 12).announce_finished(4).unwrap();
            assert_eq!(high.lowest_finisher(4).unwrap(), Some(3), "{label}");
            // markers are level-scoped
            assert_eq!(high.lowest_finisher(5).unwrap(), None, "{label}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn double_commit_is_rejected_and_commit_checked_tolerates_races() {
        let dir = tmpdir("commit");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut a = ShardRun::open_or_create(&opts, 8, 40, 4, "Jeffreys", "aa", None).unwrap();
        // A commits level 0; B (reading the committed state) has its raw
        // double commit rejected…
        a.commit_level(0).unwrap();
        let mut b = ShardRun::open(&dir).unwrap();
        let err = b.commit_level(0).unwrap_err().to_string();
        assert!(err.contains("already committed"), "{err}");
        // …but the barrier's reload-check-commit treats it as the benign
        // race it is
        let mut b = ShardRun::open(&dir).unwrap();
        assert!(!commit_checked(&mut b, 0).unwrap());
        assert_eq!(b.completed, Some(0));
        // and a genuinely out-of-order commit is still an error
        let err = commit_checked(&mut b, 5).unwrap_err().to_string();
        assert!(err.contains("out of order"), "{err}");
        // the in-order next level goes through
        assert!(commit_checked(&mut b, 1).unwrap());
        assert_eq!(committed_level(&posix(&dir)), Some(1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn commit_checked_repairs_a_regressed_manifest_instead_of_wedging() {
        let dir = tmpdir("repair");
        let opts = ShardOptions {
            shards: 2,
            dir: dir.clone(),
            ..Default::default()
        };
        let mut run = ShardRun::open_or_create(&opts, 8, 40, 4, "Jeffreys", "bb", None).unwrap();
        run.commit_level(0).unwrap();
        run.commit_level(1).unwrap();
        // simulate a stalled committer's late publish landing an OLD
        // manifest over the new one: levels_complete rolls back 1 → 0
        let manifest = dir.join("manifest.json");
        let rolled = std::fs::read_to_string(&manifest)
            .unwrap()
            .replace("\"levels_complete\": 1", "\"levels_complete\": 0");
        std::fs::write(&manifest, rolled).unwrap();
        assert_eq!(committed_level(&posix(&dir)), Some(0), "regression in place");
        // a host that observed level 1 commit repairs forward and
        // commits level 2 instead of bailing 'out of order'
        assert!(commit_checked(&mut run, 2).unwrap());
        assert_eq!(committed_level(&posix(&dir)), Some(2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cleanup_removes_ledger_keys_but_not_shard_data() {
        for (label, store, dir) in stores("cleanup") {
            let a = ledger(&store, 0);
            let claim = match a.try_claim(3, 0).unwrap() {
                ClaimState::Claimed(c) => c,
                other => panic!("{label}: {other:?}"),
            };
            a.mark_done(&claim, 1, 1).unwrap();
            a.announce_finished(3).unwrap();
            store.put_doc("claim-03-0001.json", b"{}").unwrap();
            store
                .put_doc("claim-03-0002.json.stale-1-99", b"{}")
                .unwrap();
            store.put_doc("level_03_shard_0000.sink", b"data").unwrap();
            store
                .put_doc("level_03_shard_0001.qr.host-0009-1-7", b"stray")
                .unwrap();
            // a zombie's late publish resurrected a pruned frontier file
            store
                .put_doc("level_03_shard_0001.qr", b"resurrected")
                .unwrap();
            store.put_doc("done-04-0000.json", b"{}").unwrap();
            cleanup_level(&store, 3, true);
            let names = store.list("").unwrap();
            assert!(
                names.contains(&"level_03_shard_0000.sink".to_string()),
                "{label}: sink data survives cleanup: {names:?}"
            );
            assert!(
                names.contains(&"done-04-0000.json".to_string()),
                "{label}: other levels' ledgers survive: {names:?}"
            );
            for gone in [
                "claim-03-0001.json",
                "claim-03-0002.json.stale-1-99",
                "done-03-0000.json",
                "finish-03-host-0000.json",
                "level_03_shard_0001.qr.host-0009-1-7",
                "level_03_shard_0001.qr",
            ] {
                assert!(
                    !names.contains(&gone.to_string()),
                    "{label}: {gone} not cleaned: {names:?}"
                );
            }
            // without prune_frontier (the final level), .qr streams survive
            store.put_doc("level_05_shard_0000.qr", b"final score").unwrap();
            store.put_doc("done-05-0000.json", b"{}").unwrap();
            cleanup_level(&store, 5, false);
            assert!(store.exists("level_05_shard_0000.qr").unwrap(), "{label}");
            assert!(!store.exists("done-05-0000.json").unwrap(), "{label}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    /// The listing-lag satellite: after a level's ledger is cleaned, a
    /// lagging LIST that still shows the deleted keys must not be able
    /// to resurrect anything — deletes are idempotent, the authoritative
    /// probes say gone, and the next consistent LIST is clean.
    #[test]
    fn stale_listing_cannot_resurrect_a_cleaned_level() {
        let dir = tmpdir("ghost_cleanup");
        let object = Arc::new(ObjectBackend::with_faults(&dir, ObjectFaults::default()));
        let store: SharedBackend = object.clone();
        let a = ledger(&store, 0);
        let claim = match a.try_claim(6, 0).unwrap() {
            ClaimState::Claimed(c) => c,
            other => panic!("{other:?}"),
        };
        a.mark_done(&claim, 1, 1).unwrap();
        a.announce_finished(6).unwrap();
        store.put_doc("level_06_shard_0000.sink", b"data").unwrap();
        cleanup_level(&store, 6, true);
        assert!(!store.exists("done-06-0000.json").unwrap());
        // every subsequent LIST lags: ghosts of the cleaned ledger appear
        object.faults().list_ghosts.store(3, std::sync::atomic::Ordering::Relaxed);
        // a second cleanup sweep over the ghost listing is harmless
        cleanup_level(&store, 6, true);
        // the election may see a ghost finish marker — that is a hint
        // only; the shard-state probes stay authoritative
        let _ = a.lowest_finisher(6).unwrap();
        assert!(
            !store.exists("finish-06-host-0000.json").unwrap(),
            "ghost listing resurrects nothing"
        );
        assert!(
            !store.exists("done-06-0000.json").unwrap(),
            "done markers stay deleted under ghost listings"
        );
        assert!(
            store.exists("level_06_shard_0000.sink").unwrap(),
            "sink data untouched by the ghost sweeps"
        );
        // once the lag expires the listing converges to clean
        object.faults().list_ghosts.store(0, std::sync::atomic::Ordering::Relaxed);
        let names = store.list("").unwrap();
        assert_eq!(
            names,
            vec!["level_06_shard_0000.sink".to_string()],
            "{names:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A lost conditional PUT (the injected race) must surface as Busy —
    /// never as a phantom claim — and the next attempt wins normally.
    #[test]
    fn lost_put_race_surfaces_as_busy_then_retry_wins() {
        let dir = tmpdir("putrace");
        let object = Arc::new(ObjectBackend::with_faults(&dir, ObjectFaults::default()));
        let store: SharedBackend = object.clone();
        let a = ledger(&store, 0);
        object.faults().put_races.store(1, std::sync::atomic::Ordering::Relaxed);
        assert!(
            matches!(a.try_claim(2, 1).unwrap(), ClaimState::Busy),
            "the lost PUT reads as contention, not ownership"
        );
        assert!(
            matches!(a.try_claim(2, 1).unwrap(), ClaimState::Claimed(_)),
            "the retry claims once the race is over"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_or_create_shared_initialises_exactly_once_across_hosts() {
        let dir = tmpdir("init");
        let mk = |host: usize| ClusterOptions {
            shard: ShardOptions {
                shards: 2,
                dir: dir.clone(),
                hosts: 4,
                ..Default::default()
            },
            host_id: host,
            heartbeat: Duration::from_millis(200),
            poll: Duration::from_millis(2),
        };
        let runs: Vec<ShardRun> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|host| {
                    let mk = &mk;
                    scope.spawn(move || {
                        open_or_create_shared(&mk(host), 10, 50, 4, "Jeffreys", "f00f", None).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for run in &runs {
            assert_eq!(run.p, 10);
            assert_eq!(run.shards, 2);
            assert_eq!(run.completed, None);
        }
        assert!(!dir.join("cluster-init.lock").exists(), "lock released");
        // a stale abandoned lock does not wedge a later initialisation,
        // and a crashed committer's old manifest temp is swept on entry
        let dir2 = tmpdir("init_stale");
        let seed = posix(&dir2);
        seed.put_doc("cluster-init.lock", b"{}").unwrap();
        seed.backdate("cluster-init.lock", Duration::from_secs(3600));
        seed.put_doc("manifest.json.tmp.99.0", b"{}").unwrap();
        seed.backdate("manifest.json.tmp.99.0", Duration::from_secs(3600));
        let opts = ClusterOptions {
            shard: ShardOptions {
                shards: 2,
                dir: dir2.clone(),
                ..Default::default()
            },
            heartbeat: Duration::from_millis(100),
            poll: Duration::from_millis(2),
            ..Default::default()
        };
        let run = open_or_create_shared(&opts, 6, 20, 4, "Bic", "0ff0", None).unwrap();
        assert_eq!(run.p, 6);
        assert!(
            !dir2.join("manifest.json.tmp.99.0").exists(),
            "crashed committer's temp swept"
        );
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    /// The same exactly-once initialisation, on the object backend: four
    /// in-process hosts race conditional PUTs for the init lock.
    #[test]
    fn object_init_lock_is_exactly_once_too() {
        let dir = tmpdir("init_object");
        let mk = |host: usize| ClusterOptions {
            shard: ShardOptions {
                shards: 2,
                dir: dir.clone(),
                backend: crate::coordinator::storage::BackendKind::Object,
                ..Default::default()
            },
            host_id: host,
            heartbeat: Duration::from_millis(200),
            poll: Duration::from_millis(2),
        };
        let runs: Vec<ShardRun> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|host| {
                    let mk = &mk;
                    scope.spawn(move || {
                        open_or_create_shared(&mk(host), 9, 30, 4, "Bic", "beef", None).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for run in &runs {
            assert_eq!(run.p, 9);
            assert_eq!(run.shards, 2);
        }
        assert!(!dir.join("cluster-init.lock").exists(), "lock released");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
