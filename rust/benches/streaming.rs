//! Bench — the streaming engine's flagship claim (ISSUE 6): the same
//! bit-identical optimum as the resident `LeveledSolver`, at a heap
//! peak strictly below it, with the analytic
//! [`bnsl::coordinator::plan::streaming_plan`] model matching the
//! solver's own peak accounting byte for byte.
//!
//! The heap peaks come from [`bnsl::memtrack::TrackingAlloc`]
//! (deterministic high-water marks, not RSS), so the win is assertable
//! in CI. Container-feasible default is `BNSL_SOLVE_P=14`; the ISSUE's
//! p = 20–24 demonstration is the same binary with `BNSL_SOLVE_P=20`
//! on a larger host.

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::coordinator::plan::{memory_plan, streaming_plan};
use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::solver::{LeveledSolver, StreamingSolver};
use bnsl::util::human_bytes;
use bnsl::util::json::Json;

fn main() {
    let p: usize = std::env::var("BNSL_SOLVE_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(14);
    let n: usize = std::env::var("BNSL_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let d = synth::binary(p, n, 4807);
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let subsets = (1u64 << p) as f64;

    println!("=== streaming vs resident leveled solve, p = {p}, n = {n} ===\n");
    let (leveled, leveled_heap) =
        bnsl::memtrack::measure(|| LeveledSolver::new(&e).solve());
    let (streaming, streaming_heap) =
        bnsl::memtrack::measure(|| StreamingSolver::new(&e).solve());

    // Bit-identity is the contract: both paths share one LevelWorker
    // inner loop, so the optimum, the network and the counters agree
    // exactly — not approximately.
    assert_eq!(
        leveled.log_score.to_bits(),
        streaming.log_score.to_bits(),
        "streaming drifted from the resident solver"
    );
    assert_eq!(leveled.network, streaming.network, "networks differ");
    assert_eq!(leveled.order, streaming.order, "orders differ");
    assert_eq!(
        leveled.stats.score_evals, streaming.stats.score_evals,
        "eval counts differ"
    );

    // The memory claim, twice over: the solver's own peak accounting
    // must equal the analytic plan model exactly, and the *measured*
    // allocator high-water mark must undercut the resident solver's.
    let plan = streaming_plan(p);
    let resident_plan = memory_plan(p, 0.0);
    assert_eq!(
        streaming.stats.peak_state_bytes as u64, plan.peak_bytes,
        "solver accounting disagrees with streaming_plan"
    );
    assert!(
        plan.peak_bytes < resident_plan.peak_bytes,
        "streaming plan ({}) must undercut the resident plan ({})",
        plan.peak_bytes,
        resident_plan.peak_bytes
    );
    assert!(
        streaming_heap < leveled_heap,
        "measured streaming heap ({streaming_heap}) must undercut the \
         resident solver's ({leveled_heap})"
    );
    assert!(
        plan.peak_bytes <= streaming_heap as u64,
        "the plan's state model ({}) cannot exceed the measured heap \
         peak ({streaming_heap})",
        plan.peak_bytes
    );

    let leveled_ns = leveled.stats.wall.as_secs_f64() / subsets * 1e9;
    let streaming_ns = streaming.stats.wall.as_secs_f64() / subsets * 1e9;
    println!(
        "resident : {leveled_ns:8.1} ns/subset  heap peak {}",
        human_bytes(leveled_heap as u64)
    );
    println!(
        "streaming: {streaming_ns:8.1} ns/subset  heap peak {} ({:+.1}% wall vs resident)",
        human_bytes(streaming_heap as u64),
        (streaming_ns / leveled_ns - 1.0) * 100.0
    );
    println!(
        "plan     : peak {} at level {} (record streams {} vs {} resident sink tables)",
        human_bytes(plan.peak_bytes),
        plan.peak_level,
        human_bytes(plan.record_stream_bytes),
        human_bytes(plan.resident_sink_bytes)
    );

    // CI bench-smoke: machine-readable record for the perf trajectory
    // (tools/bench_smoke.sh merges it into BENCH_ci.json, gated by
    // tools/bench_compare.py against BENCH_baseline.json).
    if let Ok(path) = std::env::var("BNSL_BENCH_JSON") {
        let doc = Json::obj()
            .set("bench", "streaming")
            .set("solve_p", p)
            .set("n", n)
            .set("streaming_ns_per_subset", streaming_ns)
            .set("leveled_ns_per_subset", leveled_ns)
            .set("streaming_heap_peak_bytes", streaming_heap)
            .set("leveled_heap_peak_bytes", leveled_heap)
            .set("plan_peak_bytes", plan.peak_bytes)
            .set("plan_record_stream_bytes", plan.record_stream_bytes);
        std::fs::write(&path, doc.to_pretty()).expect("writing BNSL_BENCH_JSON");
        println!("bench record: {path}");
    }
}
