//! Bench — the ordering-based search tier (ISSUE 9): seeded OBS on the
//! planted-chain fixture, reporting wall time and the achieved-score /
//! optimal-score ratio against the exact leveled DP. The ratio gates as
//! a FLOOR in tools/bench_compare.py — a search regression that quietly
//! degrades the anytime incumbent fails CI like a wall regression.
//!
//! The bench also asserts the two properties the service tier rests on:
//! the search is deterministic (same seed → bit-identical score), and
//! it never beats the proven optimum (admissibility of the incumbent).
//! Container-feasible default is `BNSL_SOLVE_P=14`.

use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::search::{ordering_search, OrderingOptions};
use bnsl::solver::LeveledSolver;
use bnsl::util::json::Json;
use std::time::Instant;

fn main() {
    let p: usize = std::env::var("BNSL_SOLVE_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(14);
    let n: usize = std::env::var("BNSL_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let d = synth::chain(p, n, 0.95, 3);
    let kind = ScoreKind::Jeffreys;

    println!("=== ordering search (OBS), p = {p}, n = {n} (planted chain) ===\n");

    let t = Instant::now();
    let obs = ordering_search(&d, kind, &OrderingOptions::default());
    let ordering_wall = t.elapsed().as_secs_f64();

    // determinism: the service fingerprints assume same input + options
    // → bit-identical search output
    let again = ordering_search(&d, kind, &OrderingOptions::default());
    assert_eq!(
        obs.log_score.to_bits(),
        again.log_score.to_bits(),
        "seeded OBS must be deterministic"
    );
    assert_eq!(obs.network, again.network, "seeded OBS must be deterministic");

    let e = NativeEngine::new(&d, kind);
    let t = Instant::now();
    let exact = LeveledSolver::new(&e).solve();
    let exact_wall = t.elapsed().as_secs_f64();

    // admissibility: the incumbent the anytime tier serves (and the
    // BFBnB prune gate trusts) must never exceed the true optimum
    assert!(
        obs.log_score <= exact.log_score + 1e-9,
        "OBS {} beats the exact optimum {}",
        obs.log_score,
        exact.log_score
    );
    // both log-scores are negative, so optimal/achieved ∈ (0, 1] with
    // 1.0 = the search found the optimum; higher is better (FLOOR gate)
    let ratio = exact.log_score / obs.log_score;
    assert!(
        (0.0..=1.0 + 1e-12).contains(&ratio),
        "score ratio {ratio} out of range (achieved {}, optimal {})",
        obs.log_score,
        exact.log_score
    );
    assert!(
        ratio > 0.5,
        "OBS landed implausibly far from the optimum: ratio {ratio:.4}"
    );

    println!("ordering : {ordering_wall:7.3}s  log-score {:.6}", obs.log_score);
    println!("exact    : {exact_wall:7.3}s  log-score {:.6}", exact.log_score);
    println!(
        "ratio    : {ratio:.6} (optimal/achieved; 1.0 = search found the optimum)"
    );
    println!(
        "work     : {} families evaluated, {} swaps taken",
        obs.families_evaluated, obs.swaps_taken
    );

    // CI bench-smoke: machine-readable record for the perf trajectory
    // (tools/bench_smoke.sh merges it into BENCH_ci.json; score_ratio
    // gates as a floor in tools/bench_compare.py)
    if let Ok(path) = std::env::var("BNSL_BENCH_JSON") {
        let doc = Json::obj()
            .set("bench", "ordering")
            .set("solve_p", p)
            .set("n", n)
            .set("ordering_wall_secs", ordering_wall)
            .set("exact_wall_secs", exact_wall)
            .set("score_ratio", ratio)
            .set("achieved_log_score", obs.log_score)
            .set("optimal_log_score", exact.log_score)
            .set("families_evaluated", obs.families_evaluated)
            .set("swaps_taken", obs.swaps_taken);
        std::fs::write(&path, doc.to_pretty()).expect("writing BNSL_BENCH_JSON");
        println!("bench record: {path}");
    }
}
