//! Bench — the bounds layer (ISSUE 8): the pruned solve must land on
//! the dense optimum bit for bit while actually skipping record
//! emission. Runs the resident and the sharded solver with pruning off
//! and on at the same configuration, asserts bit-identity and a nonzero
//! measured prune ratio, and reports the ratio plus the on-disk shard
//! footprint of both sharded runs.
//!
//! The prune ratio is data-dependent, so the planted chain (strong
//! structure, deterministic seed) is the workload: its mid-lattice is
//! heavily dominated and the hillclimb incumbent sits at or near the
//! optimum. Container-feasible default is `BNSL_SOLVE_P=14`.

use bnsl::coordinator::shard::{ShardOptions, ShardOutcome};
use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::solver::{solve_sharded, LeveledSolver, PruneMode, SolveOptions, SolveResult};
use bnsl::util::human_bytes;
use bnsl::util::json::Json;
use std::time::Instant;

/// Total bytes of every regular file under `dir`, recursively.
fn dir_bytes(dir: &std::path::Path) -> u64 {
    let mut total = 0u64;
    let Ok(entries) = std::fs::read_dir(dir) else {
        return 0;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            total += dir_bytes(&path);
        } else if let Ok(meta) = entry.metadata() {
            total += meta.len();
        }
    }
    total
}

fn assert_identical(tag: &str, dense: &SolveResult, pruned: &SolveResult) {
    assert_eq!(
        dense.log_score.to_bits(),
        pruned.log_score.to_bits(),
        "{tag}: pruning moved the optimum"
    );
    assert_eq!(dense.network, pruned.network, "{tag}: networks differ");
    assert_eq!(dense.order, pruned.order, "{tag}: orders differ");
}

fn main() {
    let p: usize = std::env::var("BNSL_SOLVE_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(14);
    let n: usize = std::env::var("BNSL_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let d = synth::chain(p, n, 0.95, 3);
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);

    println!("=== bounds-layer pruning, p = {p}, n = {n} (planted chain) ===\n");

    // resident: dense vs pruned
    let t = Instant::now();
    let dense = LeveledSolver::new(&e).solve();
    let resident_dense_wall = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let pruned = LeveledSolver::with_options(
        &e,
        SolveOptions {
            prune: PruneMode::Auto,
            ..Default::default()
        },
    )
    .solve();
    let resident_pruned_wall = t.elapsed().as_secs_f64();
    assert_identical("resident", &dense, &pruned);
    assert!(
        pruned.stats.pruned_subsets > 0,
        "the planted chain must prune at least one subset"
    );
    let ratio = pruned.stats.pruned_subsets as f64 / pruned.stats.prune_considered as f64;

    // sharded: dense vs pruned, with the on-disk footprint of each run
    // (keep_levels so the comparison covers every level's shard files)
    let scratch = std::env::temp_dir().join(format!("bnsl_bench_prune_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    let mut sharded = |mode: PruneMode| -> (SolveResult, f64, u64) {
        let dir = scratch.join(match mode {
            PruneMode::Off => "dense",
            _ => "pruned",
        });
        let t = Instant::now();
        let out = solve_sharded::<u32>(
            &e,
            &ShardOptions {
                shards: 4,
                dir: dir.clone(),
                keep_levels: true,
                prune: mode,
                ..Default::default()
            },
        )
        .expect("sharded solve");
        let wall = t.elapsed().as_secs_f64();
        let ShardOutcome::Complete(result) = out else {
            panic!("sharded run checkpointed without a stop request");
        };
        (result, wall, dir_bytes(&dir))
    };
    let (sharded_dense, sharded_dense_wall, dense_bytes) = sharded(PruneMode::Off);
    let (sharded_pruned, sharded_pruned_wall, pruned_bytes) = sharded(PruneMode::Auto);
    let _ = std::fs::remove_dir_all(&scratch);
    assert_identical("sharded dense vs resident", &dense, &sharded_dense);
    assert_identical("sharded pruned vs resident", &dense, &sharded_pruned);
    assert!(
        sharded_pruned.stats.pruned_subsets > 0,
        "the sharded run must report a nonzero prune count"
    );
    // the `.prn` presence maps cost ~0.13 bytes/rank; once the measured
    // ratio clears 1% the skipped bps/sink records must dominate that
    if ratio >= 0.01 {
        assert!(
            pruned_bytes < dense_bytes,
            "ratio {ratio:.3} but pruned run bytes ({pruned_bytes}) did not \
             undercut the dense run's ({dense_bytes})"
        );
    }

    println!(
        "resident : dense {resident_dense_wall:7.3}s  pruned {resident_pruned_wall:7.3}s"
    );
    println!(
        "sharded  : dense {sharded_dense_wall:7.3}s  pruned {sharded_pruned_wall:7.3}s"
    );
    println!(
        "pruned   : {} of {} bound-checked subsets ({:.1}%)",
        pruned.stats.pruned_subsets,
        pruned.stats.prune_considered,
        ratio * 100.0
    );
    println!(
        "disk     : dense {}  pruned {}",
        human_bytes(dense_bytes),
        human_bytes(pruned_bytes)
    );

    // CI bench-smoke: machine-readable record for the perf trajectory
    // (tools/bench_smoke.sh merges it into BENCH_ci.json; the measured
    // prune_ratio gates as a floor in tools/bench_compare.py — a bounds
    // regression that stops pruning fails CI like a wall regression).
    if let Ok(path) = std::env::var("BNSL_BENCH_JSON") {
        let doc = Json::obj()
            .set("bench", "prune")
            .set("solve_p", p)
            .set("n", n)
            .set("prune_ratio", ratio)
            .set("pruned_subsets", pruned.stats.pruned_subsets)
            .set("prune_considered", pruned.stats.prune_considered)
            .set("resident_dense_wall_secs", resident_dense_wall)
            .set("resident_pruned_wall_secs", resident_pruned_wall)
            .set("sharded_dense_wall_secs", sharded_dense_wall)
            .set("sharded_pruned_wall_secs", sharded_pruned_wall)
            .set("dense_shard_bytes", dense_bytes)
            .set("pruned_shard_bytes", pruned_bytes);
        std::fs::write(&path, doc.to_pretty()).expect("writing BNSL_BENCH_JSON");
        println!("bench record: {path}");
    }
}
