//! Scoring micro-benchmarks (the perf-pass instrument, EXPERIMENTS.md
//! §Perf): per-subset cost of the native engine's counting strategies
//! and, when artifacts are built, the PJRT/Pallas path.

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::cli::exp::{alarm_data, engine_bench};
use bnsl::data::Dataset;
use bnsl::score::counts::Counter;
use bnsl::score::{LocalScorer, ScoreKind};
use bnsl::util::table::Table;
use std::time::Instant;

fn time_counter(data: &Dataset, masks: &[u32], mut counter: Counter) -> f64 {
    let t0 = Instant::now();
    let mut sink = 0u64;
    for &m in masks {
        sink += counter.count(data, m).len() as u64;
    }
    std::hint::black_box(sink);
    t0.elapsed().as_secs_f64() / masks.len() as f64
}

fn main() {
    let p = 20;
    let n = 200;
    let data = alarm_data(p, n, 2024);
    // representative mid-lattice masks (where the DP spends its time)
    let masks: Vec<u32> = bnsl::bitset::LevelIter::new(p, p / 2).take(200_000).collect();
    println!("=== scoring micro-bench: p={p}, n={n}, {} masks of size {} ===\n", masks.len(), p / 2);

    let mut table = Table::new(vec!["path", "ns/subset", "subsets/s"]);
    let hash = time_counter(&data, &masks, Counter::new(n));
    let sort = time_counter(&data, &masks, Counter::new(n).with_sort_strategy());
    table.row(vec![
        "count: open-addressing".to_string(),
        format!("{:.0}", hash * 1e9),
        format!("{:.2e}", 1.0 / hash),
    ]);
    table.row(vec![
        "count: sort+runlength".to_string(),
        format!("{:.0}", sort * 1e9),
        format!("{:.2e}", 1.0 / sort),
    ]);

    // full Jeffreys scoring (count + lgamma + σ)
    let mut scorer = LocalScorer::new(&data, ScoreKind::Jeffreys);
    let t0 = Instant::now();
    let mut acc = 0.0;
    for &m in &masks {
        acc += scorer.log_q(m);
    }
    std::hint::black_box(acc);
    let per = t0.elapsed().as_secs_f64() / masks.len() as f64;
    table.row(vec![
        "native log Q (full)".to_string(),
        format!("{:.0}", per * 1e9),
        format!("{:.2e}", 1.0 / per),
    ]);

    // PJRT path on a smaller sample (interpret-mode Pallas is slow)
    let small: Vec<u32> = masks.iter().copied().take(512).collect();
    let (native_per, jax_per) = engine_bench(&data, &small, std::path::Path::new("artifacts"));
    table.row(vec![
        "native log Q (512-batch)".to_string(),
        format!("{:.0}", native_per * 1e9),
        format!("{:.2e}", 1.0 / native_per),
    ]);
    match jax_per {
        Some(jp) => {
            table.row(vec![
                "jax/PJRT log Q (512-batch)".to_string(),
                format!("{:.0}", jp * 1e9),
                format!("{:.2e}", 1.0 / jp),
            ]);
        }
        None => println!("(PJRT path skipped: run `make artifacts`)"),
    }
    println!("{}", table.render());
    println!("note: the jax path runs the Pallas kernel under interpret=True —");
    println!("a correctness vehicle; real-TPU throughput is estimated in DESIGN.md.");
}
