//! Scoring micro-benchmarks (the perf-pass instrument, EXPERIMENTS.md
//! §Perf): per-subset cost of the native engine's counting strategies
//! and, when artifacts are built, the PJRT/Pallas path.

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::cli::exp::{alarm_data, engine_bench};
use bnsl::data::Dataset;
use bnsl::score::counts::Counter;
use bnsl::score::{LocalScorer, ScoreKind};
use bnsl::util::json::Json;
use bnsl::util::table::Table;
use std::time::Instant;

fn time_counter(data: &Dataset, masks: &[u32], mut counter: Counter) -> f64 {
    let t0 = Instant::now();
    let mut sink = 0u64;
    for &m in masks {
        sink += counter.count(data, m).len() as u64;
    }
    std::hint::black_box(sink);
    t0.elapsed().as_secs_f64() / masks.len() as f64
}

fn main() {
    let p = 20;
    let n = 200;
    let data = alarm_data(p, n, 2024);
    // representative mid-lattice masks (where the DP spends its time)
    let masks: Vec<u32> = bnsl::bitset::LevelIter::new(p, p / 2).take(200_000).collect();
    println!("=== scoring micro-bench: p={p}, n={n}, {} masks of size {} ===\n", masks.len(), p / 2);

    let mut table = Table::new(vec!["path", "ns/subset", "subsets/s"]);
    let hash = time_counter(&data, &masks, Counter::new(n));
    let sort = time_counter(&data, &masks, Counter::new(n).with_sort_strategy());
    table.row(vec![
        "count: open-addressing".to_string(),
        format!("{:.0}", hash * 1e9),
        format!("{:.2e}", 1.0 / hash),
    ]);
    table.row(vec![
        "count: sort+runlength".to_string(),
        format!("{:.0}", sort * 1e9),
        format!("{:.2e}", 1.0 / sort),
    ]);

    // full Jeffreys scoring (count + lgamma + σ)
    let mut scorer = LocalScorer::new(&data, ScoreKind::Jeffreys);
    let t0 = Instant::now();
    let mut acc = 0.0;
    for &m in &masks {
        acc += scorer.log_q(m);
    }
    std::hint::black_box(acc);
    let per = t0.elapsed().as_secs_f64() / masks.len() as f64;
    table.row(vec![
        "native log Q (full)".to_string(),
        format!("{:.0}", per * 1e9),
        format!("{:.2e}", 1.0 / per),
    ]);

    // batched kernel entry point: the same subsets through
    // log_q_batch_into in solver-sized chunks (one call per batch, the
    // cache-blocked encode inside). Must be bit-identical to the
    // one-at-a-time accumulation above.
    let mut batch_scorer = LocalScorer::new(&data, ScoreKind::Jeffreys);
    let mut out = vec![0.0; 1024];
    let t0 = Instant::now();
    let mut batch_acc = 0.0;
    for chunk in masks.chunks(1024) {
        let slots = &mut out[..chunk.len()];
        batch_scorer.log_q_batch_into(chunk, slots);
        for v in slots.iter() {
            batch_acc += *v;
        }
    }
    std::hint::black_box(batch_acc);
    let batch_per = t0.elapsed().as_secs_f64() / masks.len() as f64;
    assert_eq!(
        acc.to_bits(),
        batch_acc.to_bits(),
        "batched kernel drifted from the single-subset path"
    );
    table.row(vec![
        "native log Q (batched kernel)".to_string(),
        format!("{:.0}", batch_per * 1e9),
        format!("{:.2e}", 1.0 / batch_per),
    ]);

    // PJRT path on a smaller sample (interpret-mode Pallas is slow)
    let small: Vec<u32> = masks.iter().copied().take(512).collect();
    let (native_per, jax_per) = engine_bench(&data, &small, std::path::Path::new("artifacts"));
    table.row(vec![
        "native log Q (512-batch)".to_string(),
        format!("{:.0}", native_per * 1e9),
        format!("{:.2e}", 1.0 / native_per),
    ]);
    match jax_per {
        Some(jp) => {
            table.row(vec![
                "jax/PJRT log Q (512-batch)".to_string(),
                format!("{:.0}", jp * 1e9),
                format!("{:.2e}", 1.0 / jp),
            ]);
        }
        None => println!("(PJRT path skipped: run `make artifacts`)"),
    }
    println!("{}", table.render());
    println!("note: the jax path runs the Pallas kernel under interpret=True —");
    println!("a correctness vehicle; real-TPU throughput is estimated in DESIGN.md.");

    // CI bench-smoke: machine-readable record for the perf trajectory
    // (tools/bench_smoke.sh merges it into BENCH_ci.json, gated by
    // tools/bench_compare.py against BENCH_baseline.json).
    if let Ok(path) = std::env::var("BNSL_BENCH_JSON") {
        let doc = Json::obj()
            .set("bench", "scoring")
            .set("p", p)
            .set("n", n)
            .set("masks", masks.len())
            .set("hash_ns_per_subset", hash * 1e9)
            .set("sort_ns_per_subset", sort * 1e9)
            .set("log_q_ns_per_subset", per * 1e9)
            .set("batch_log_q_ns_per_subset", batch_per * 1e9);
        std::fs::write(&path, doc.to_pretty()).expect("writing BNSL_BENCH_JSON");
        println!("bench record: {path}");
    }
}
