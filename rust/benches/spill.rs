//! Bench E7 — the paper's **§5.3 extension**: spill the best-parent-set
//! vectors of near-peak levels to disk. Measures the peak-memory saving
//! and the time cost against the all-in-RAM proposed method.
//!
//! Paper: "the proposed method can reduce the memory peak by using the
//! disk only at the peak or near-peak levels" (vectors shorter ⇒ easier
//! to read/write than the existing method's full-lattice spills).

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::cli::exp::{spill, ExpConfig};

fn env(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let pmin = env("BNSL_PMIN", 15);
    let pmax = env("BNSL_PMAX", 18);
    let threshold: f64 = std::env::var("BNSL_THRESHOLD")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.5);
    let cfg = ExpConfig {
        out_dir: std::path::PathBuf::from("results"),
        ..Default::default()
    };
    println!("=== §5.3: disk spill at near-peak levels (threshold {threshold}) ===\n");
    let table = spill(&cfg, pmin, pmax, threshold).expect("spill bench failed");
    println!("{}", table.render());
    println!("records: results/spill.json");
}
