//! Bench E5 — validates **Table 1**: operation counts against the
//! Appendix-A closed forms, and traversal counts (proposed = 1,
//! existing = 3 passes over the subset lattice).

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::cli::exp::{complexity, ExpConfig};

fn main() {
    let pmin: usize = std::env::var("BNSL_PMIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let pmax: usize = std::env::var("BNSL_PMAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let cfg = ExpConfig {
        out_dir: std::path::PathBuf::from("results"),
        ..Default::default()
    };
    println!("=== Table 1: computation counters vs closed forms ===");
    println!("both methods: O(p²2^p) compute; bps updates must equal p(p−1)2^(p−2)\n");
    let table = complexity(&cfg, pmin, pmax).expect("complexity failed");
    println!("{}", table.render());
    println!("memory: proposed O(√p·2^p) vs existing O(p·2^p) — see bench levels/table2");
}
