//! Bench E1 — regenerates **Table 2 / Fig. 4(a,b)**: wall time and peak
//! memory of the existing (Silander–Myllymäki) vs proposed (leveled)
//! method on ALARM prefixes, n = 200.
//!
//! Default range is container-scale (p = 14…19, ~seconds each). The
//! paper's exact range:  BNSL_PMIN=20 BNSL_PMAX=25 BNSL_RUNS=10 cargo bench --bench table2

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::cli::exp::{table2, ExpConfig};

fn env(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let pmin = env("BNSL_PMIN", 14);
    let pmax = env("BNSL_PMAX", 19);
    let runs = env("BNSL_RUNS", 3);
    let cfg = ExpConfig {
        out_dir: std::path::PathBuf::from("results"),
        ..Default::default()
    };
    println!("=== Table 2 / Fig 4: existing vs proposed (n = {}, {} runs) ===", cfg.n, runs);
    println!("paper @ p=20..25: time 7.5→285.7 min vs 5.2→217.7 min (1.3–1.6x),");
    println!("                  mem 148→5810 MB vs 85→1290 MB (1.7→4.5x)\n");
    let table = table2(&cfg, pmin, pmax, runs).expect("table2 failed");
    println!("{}", table.render());
    println!("records: results/table2.json");
}
