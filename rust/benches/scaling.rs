//! Bench — the scaling curve behind the eval harness (ISSUE 7): wall
//! time and heap peak versus p for every execution mode (resident,
//! streaming, spill-assisted, sharded), on one shared synthetic dataset
//! family. Every mode must land on the same optimum bit for bit — the
//! curve compares *costs*, never results.
//!
//! Defaults are container-sized (`BNSL_SCALING_PS=10,12,14`, n = 64);
//! the paper-scale curve is the same binary with e.g.
//! `BNSL_SCALING_PS=18,22,26` on a larger host. `BNSL_BENCH_JSON=path`
//! writes the machine-readable rows that `tools/bench_smoke.sh` merges
//! into `BENCH_ci.json` and derives the `BENCH_scaling.csv` artifact
//! from.

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::coordinator::shard::{ShardOptions, ShardOutcome};
use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::solver::{solve_sharded, LeveledSolver, SolveOptions, StreamingSolver};
use bnsl::util::human_bytes;
use bnsl::util::json::Json;

struct Row {
    p: usize,
    mode: &'static str,
    wall_secs: f64,
    heap_peak_bytes: usize,
    log_score: f64,
}

fn main() {
    let ps: Vec<usize> = std::env::var("BNSL_SCALING_PS")
        .unwrap_or_else(|_| "10,12,14".into())
        .split(',')
        .map(|t| t.trim().parse().expect("BNSL_SCALING_PS: comma-separated p list"))
        .collect();
    let n: usize = std::env::var("BNSL_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let scratch = std::env::temp_dir().join(format!("bnsl_scaling_{}", std::process::id()));
    let mut rows: Vec<Row> = Vec::new();

    println!("=== scaling curve: wall/heap vs p across execution modes (n = {n}) ===\n");
    for &p in &ps {
        let d = synth::binary(p, n, 4807);
        let e = NativeEngine::new(&d, ScoreKind::Jeffreys);

        let (resident, resident_heap) =
            bnsl::memtrack::measure(|| LeveledSolver::new(&e).solve());
        let (streaming, streaming_heap) =
            bnsl::memtrack::measure(|| StreamingSolver::new(&e).solve());
        let spill_dir = scratch.join(format!("spill_p{p}"));
        let (spilled, spill_heap) = bnsl::memtrack::measure(|| {
            LeveledSolver::with_options(
                &e,
                SolveOptions {
                    spill_dir: Some(spill_dir.clone()),
                    ..Default::default()
                },
            )
            .solve()
        });
        let shard_dir = scratch.join(format!("shard_p{p}"));
        let (sharded, sharded_heap) = bnsl::memtrack::measure(|| {
            match solve_sharded::<u32>(
                &e,
                &ShardOptions {
                    shards: 2,
                    dir: shard_dir.clone(),
                    ..Default::default()
                },
            )
            .expect("sharded solve")
            {
                ShardOutcome::Complete(result) => result,
                ShardOutcome::Checkpointed { .. } => {
                    unreachable!("no cancel token armed")
                }
            }
        });

        // one optimum, four roads: the whole point of the curve
        for (mode, result) in [
            ("streaming", &streaming),
            ("spill", &spilled),
            ("sharded", &sharded),
        ] {
            assert_eq!(
                resident.log_score.to_bits(),
                result.log_score.to_bits(),
                "{mode} drifted from the resident optimum at p = {p}"
            );
            assert_eq!(resident.network, result.network, "{mode} network at p = {p}");
        }

        for (mode, result, heap) in [
            ("resident", &resident, resident_heap),
            ("streaming", &streaming, streaming_heap),
            ("spill", &spilled, spill_heap),
            ("sharded", &sharded, sharded_heap),
        ] {
            let wall = result.stats.wall.as_secs_f64();
            println!(
                "p = {p:2}  {mode:9} {:8.1} ms  heap peak {}",
                wall * 1e3,
                human_bytes(heap as u64)
            );
            rows.push(Row {
                p,
                mode,
                wall_secs: wall,
                heap_peak_bytes: heap,
                log_score: result.log_score,
            });
        }
        println!();
    }
    let _ = std::fs::remove_dir_all(&scratch);

    if let Ok(path) = std::env::var("BNSL_BENCH_JSON") {
        let mut arr = Json::arr();
        for row in &rows {
            arr = arr.push(
                Json::obj()
                    .set("p", row.p)
                    .set("mode", row.mode)
                    .set("wall_secs", Json::Num(row.wall_secs))
                    .set("heap_peak_bytes", row.heap_peak_bytes)
                    .set("log_score", Json::Num(row.log_score)),
            );
        }
        let doc = Json::obj()
            .set("bench", "scaling")
            .set("n", n)
            .set("rows", arr);
        std::fs::write(&path, doc.to_pretty()).expect("writing BNSL_BENCH_JSON");
        println!("bench record: {path}");
    }
}
