//! Bench E2 — regenerates **Fig. 5 / Tables 3–4**: stability of the
//! proposed method's runtime and peak memory over repeated identical
//! runs (the paper runs each size 10 times and reports per-run values).
//!
//! Paper scale: BNSL_PS=20,21,22,23,24,25 BNSL_RUNS=10 cargo bench --bench stability

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::cli::exp::{stability, ExpConfig};

fn main() {
    let ps: Vec<usize> = std::env::var("BNSL_PS")
        .unwrap_or_else(|_| "13,14,15,16".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let runs: usize = std::env::var("BNSL_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let cfg = ExpConfig {
        out_dir: std::path::PathBuf::from("results"),
        ..Default::default()
    };
    println!("=== Fig 5 / Tables 3–4: proposed-method stability ({runs} runs per p) ===");
    println!("paper: time cv ≲ 3%, memory cv ≲ 4% across 10 runs\n");
    let table = stability(&cfg, &ps, runs).expect("stability failed");
    println!("{}", table.render());
    println!("records: results/stability.json (per-run values, as Tables 3–4)");
}
