//! Bench E4 — regenerates **Fig. 7**: the per-level combination counts
//! (and frontier bytes) for p = 29, plus the §5.1 16 GB feasibility
//! analysis (existing max 26 variables vs proposed max 28).

use bnsl::coordinator::plan::{memory_plan, MemoryPlan};
use bnsl::util::{human_bytes, table::Table};

fn main() {
    let p: usize = std::env::var("BNSL_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(29);
    println!("=== Fig 7: combinations per level, p = {p} ===\n");
    let plan = memory_plan(p, 0.5);
    let mut table = Table::new(vec!["level k", "C(p,k)", "frontier", "near-peak"]);
    for l in &plan.levels {
        table.row(vec![
            l.k.to_string(),
            l.combinations.to_string(),
            human_bytes(l.frontier_bytes),
            if l.is_peak { "*".into() } else { String::new() },
        ]);
    }
    println!("{}", table.render());
    println!(
        "peak: level {} — paper: \"the 15th level will be the peak\" (p = 29)",
        plan.peak_level
    );
    println!(
        "proposed peak {} vs baseline {}",
        human_bytes(plan.peak_bytes),
        human_bytes(plan.baseline_bytes)
    );

    println!("\n=== §5.1 feasibility on a 16 GB budget ===");
    let budget = 16u64 << 30;
    println!(
        "existing method max p: {}   (paper: 26)",
        MemoryPlan::max_p_within(budget, true)
    );
    println!(
        "proposed method max p: {}   (paper: 28)",
        MemoryPlan::max_p_within(budget, false)
    );
    println!("\npaper's own accounting for p=29 level-15 parent vectors:");
    let binom = bnsl::bitset::BinomTable::new(29);
    let bytes = binom.c(28, 14) * 29 * 8;
    println!(
        "C(28,14)·29·8 bytes = {} (paper: 8.6679 GB)",
        human_bytes(bytes)
    );
}
