//! Bench E4 — regenerates **Fig. 7**: the per-level combination counts
//! (and frontier bytes) for p = 29, plus the §5.1 16 GB feasibility
//! analysis (existing max 26 variables vs proposed max 28).
//!
//! Also tracks the **wide-mask (u64) path** for the perf trajectory:
//! the p = 33 spill-enabled memory plan (16-byte records), and a timed
//! narrow-vs-forced-wide solve at a container-feasible `BNSL_SOLVE_P`
//! (default 18, spill enabled, small n) so a monomorphization regression
//! in either hot loop shows up here. Set `BNSL_WIDE_FULL=1` on a
//! large-memory host to run the true p = 33 spilled solve.

#[global_allocator]
static ALLOC: bnsl::memtrack::TrackingAlloc = bnsl::memtrack::TrackingAlloc;

use bnsl::coordinator::plan::{memory_plan, MemoryPlan};
use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::solver::{LeveledSolver, SolveOptions};
use bnsl::util::json::Json;
use bnsl::util::{human_bytes, table::Table};

fn spill_options() -> SolveOptions {
    SolveOptions {
        spill_dir: Some(std::env::temp_dir().join(format!(
            "bnsl_levels_bench_{}",
            std::process::id()
        ))),
        spill_threshold: 0.5,
        ..Default::default()
    }
}

/// Timed solve at both widths on the same engine; returns ns/subset.
fn race_widths(p: usize, n: usize) -> (f64, f64, f64) {
    let d = synth::binary(p, n, 4807);
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let subsets = (1u64 << p) as f64;
    let narrow = LeveledSolver::new(&e).solve();
    let wide = LeveledSolver::<u64>::new_generic(&e).solve();
    let wide_spill = LeveledSolver::<u64>::with_options_generic(&e, spill_options()).solve();
    assert_eq!(
        narrow.log_score.to_bits(),
        wide.log_score.to_bits(),
        "widths disagree"
    );
    assert_eq!(
        narrow.log_score.to_bits(),
        wide_spill.log_score.to_bits(),
        "wide spill disagrees"
    );
    (
        narrow.stats.wall.as_secs_f64() / subsets * 1e9,
        wide.stats.wall.as_secs_f64() / subsets * 1e9,
        wide_spill.stats.wall.as_secs_f64() / subsets * 1e9,
    )
}

/// Telemetry overhead guard: the same resident solve with the trace
/// sink armed (per-level spans land in a temp JSONL) vs disarmed.
/// Off-wall is the min of a run before and a run after the traced one,
/// so drift penalises rather than flatters the ratio; bench_compare.py
/// gates the result like any other wall metric.
fn telemetry_overhead(p: usize, n: usize) -> f64 {
    let d = synth::binary(p, n, 4807);
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let timed = || LeveledSolver::new(&e).solve().stats.wall.as_secs_f64();
    let off_before = timed();
    let trace_path = std::env::temp_dir().join(format!(
        "bnsl_levels_bench_trace_{}.jsonl",
        std::process::id()
    ));
    bnsl::telemetry::trace::init_trace(&trace_path).expect("arming trace sink");
    let on = timed();
    bnsl::telemetry::trace::stop_trace();
    let _ = std::fs::remove_file(&trace_path);
    let off_after = timed();
    on / off_before.min(off_after)
}

fn main() {
    let p: usize = std::env::var("BNSL_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(29);
    println!("=== Fig 7: combinations per level, p = {p} ===\n");
    let plan = memory_plan(p, 0.5);
    let mut table = Table::new(vec!["level k", "C(p,k)", "frontier", "near-peak"]);
    for l in &plan.levels {
        table.row(vec![
            l.k.to_string(),
            l.combinations.to_string(),
            human_bytes(l.frontier_bytes),
            if l.is_peak { "*".into() } else { String::new() },
        ]);
    }
    println!("{}", table.render());
    println!(
        "peak: level {} — paper: \"the 15th level will be the peak\" (p = 29)",
        plan.peak_level
    );
    println!(
        "proposed peak {} vs baseline {}",
        human_bytes(plan.peak_bytes),
        human_bytes(plan.baseline_bytes)
    );

    println!("\n=== §5.1 feasibility on a 16 GB budget ===");
    let budget = 16u64 << 30;
    println!(
        "existing method max p: {}   (paper: 26)",
        MemoryPlan::max_p_within(budget, true)
    );
    println!(
        "proposed method max p: {}   (paper: 28)",
        MemoryPlan::max_p_within(budget, false)
    );
    println!("\npaper's own accounting for p=29 level-15 parent vectors:");
    let binom = bnsl::bitset::BinomTable::new(29);
    let bytes = binom.c(28, 14) * 29 * 8;
    println!(
        "C(28,14)·29·8 bytes = {} (paper: 8.6679 GB)",
        human_bytes(bytes)
    );

    // === wide-mask (u64) path ==========================================
    println!("\n=== wide path: p = 33 spill plan (u64 masks, 16-byte records) ===");
    let wide_plan = memory_plan(33, 0.5);
    assert_eq!(wide_plan.mask_bytes, 8);
    let spilled: Vec<usize> = wide_plan
        .levels
        .iter()
        .filter(|l| l.is_peak)
        .map(|l| l.k)
        .collect();
    println!(
        "peak level {} — proposed peak {} (baseline {}), near-peak levels spilled: {spilled:?}",
        wide_plan.peak_level,
        human_bytes(wide_plan.peak_bytes),
        human_bytes(wide_plan.baseline_bytes)
    );

    let solve_p: usize = std::env::var("BNSL_SOLVE_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(18);
    let n: usize = std::env::var("BNSL_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    println!("\n=== u32 vs forced-u64 solve, p = {solve_p}, n = {n} (no-regression check) ===");
    let ((narrow_ns, wide_ns, wide_spill_ns), heap_peak) =
        bnsl::memtrack::measure(|| race_widths(solve_p, n));
    println!("u32 path        : {narrow_ns:8.1} ns/subset");
    println!(
        "u64 path        : {wide_ns:8.1} ns/subset  ({:+.1}% vs u32)",
        (wide_ns / narrow_ns - 1.0) * 100.0
    );
    println!("u64 path + spill: {wide_spill_ns:8.1} ns/subset");
    println!("heap peak       : {}", human_bytes(heap_peak as u64));

    let overhead = telemetry_overhead(solve_p, n);
    println!(
        "telemetry       : traced/untraced wall ratio {overhead:.3} \
         (counters always on; spans only with a sink)"
    );

    // CI bench-smoke: append a machine-readable record so the perf
    // trajectory accumulates data points (tools/bench_smoke.sh merges
    // this with the spill bench's results/spill.json into BENCH_ci.json).
    if let Ok(path) = std::env::var("BNSL_BENCH_JSON") {
        let doc = Json::obj()
            .set("bench", "levels")
            .set("plan_p", p)
            .set("solve_p", solve_p)
            .set("n", n)
            .set("narrow_ns_per_subset", narrow_ns)
            .set("wide_ns_per_subset", wide_ns)
            .set("wide_spill_ns_per_subset", wide_spill_ns)
            .set("heap_peak_bytes", heap_peak)
            .set("plan_peak_bytes", plan.peak_bytes)
            .set("plan_baseline_bytes", plan.baseline_bytes)
            .set("telemetry_overhead_ratio", overhead);
        std::fs::write(&path, doc.to_pretty()).expect("writing BNSL_BENCH_JSON");
        println!("bench record    : {path}");
    }

    if std::env::var("BNSL_WIDE_FULL").is_ok() {
        // The real thing: 2^33 subsets, ~170 GB of tables. Only on request.
        println!("\n=== FULL p = 33 spilled solve (BNSL_WIDE_FULL set) ===");
        let mut rng = bnsl::util::rng::Rng::new(3303);
        let d = synth::random(33, 50, 2, &mut rng);
        let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
        let r = LeveledSolver::<u64>::with_options_generic(&e, spill_options()).solve();
        println!(
            "log-score {:.4}, wall {:.1}s, spilled {}",
            r.log_score,
            r.stats.wall.as_secs_f64(),
            human_bytes(r.stats.spilled_bytes)
        );
    }
}
