//! End-to-end pipeline tests: CLI-level flows on temp directories.

use bnsl::bn::repo;
use bnsl::cli::exp::{self, ExpConfig};
use bnsl::data::{read_csv, write_csv};
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::solver::{LeveledSolver, SolveOptions};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bnsl_e2e_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn sample_to_csv_to_learn_roundtrip() {
    let dir = tmp("roundtrip");
    let net = repo::asia();
    let data = net.sample(300, 21);
    let csv = dir.join("asia.csv");
    write_csv(&data, &csv).unwrap();
    let back = read_csv(&csv).unwrap();
    assert_eq!(back.p(), data.p());
    assert_eq!(back.n(), data.n());
    // arity inference can only shrink if a state never appears; scores on
    // the reloaded data must match when arities agree
    if back.arities() == data.arities() {
        let e1 = NativeEngine::new(&data, ScoreKind::Jeffreys);
        let e2 = NativeEngine::new(&back, ScoreKind::Jeffreys);
        let r1 = LeveledSolver::new(&e1).solve();
        let r2 = LeveledSolver::new(&e2).solve();
        assert_eq!(r1.log_score.to_bits(), r2.log_score.to_bits());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn experiment_records_are_valid_json_documents() {
    let dir = tmp("records");
    let cfg = ExpConfig {
        n: 50,
        out_dir: dir.clone(),
        ..Default::default()
    };
    exp::table2(&cfg, 5, 6, 1).unwrap();
    exp::stability(&cfg, &[5], 2).unwrap();
    exp::levels(&cfg, 12, 0.5).unwrap();
    for name in ["table2.json", "stability.json", "levels_p12.json"] {
        let text = std::fs::read_to_string(dir.join(name)).unwrap();
        assert!(text.trim_start().starts_with('{'), "{name}");
        assert!(text.contains("\"rows\""), "{name}");
        // cheap structural sanity: balanced braces/brackets
        let open = text.matches('{').count();
        let close = text.matches('}').count();
        assert_eq!(open, close, "{name} braces");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn paper_pipeline_small_scale_all_claims() {
    // one shot over the paper's three claims at test scale:
    //   (1) same optimum, (2) fewer traversals, (3) less frontier memory
    let dir = tmp("claims");
    let cfg = ExpConfig {
        n: 100,
        out_dir: dir.clone(),
        ..Default::default()
    };
    let data = exp::alarm_data(10, cfg.n, cfg.seed);
    let a = exp::run_solver("silander", &data, &SolveOptions::default());
    let b = exp::run_solver("leveled", &data, &SolveOptions::default());
    assert_eq!(a.result.log_score.to_bits(), b.result.log_score.to_bits());
    assert!(a.result.stats.traversals > b.result.stats.traversals);
    assert!(a.result.stats.peak_state_bytes > b.result.stats.peak_state_bytes);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spill_pipeline_at_alarm_scale() {
    let dir = tmp("spill");
    let data = exp::alarm_data(12, 150, 2024);
    let e = NativeEngine::new(&data, ScoreKind::Jeffreys);
    let plain = LeveledSolver::new(&e).solve();
    let spilled = LeveledSolver::with_options(
        &e,
        SolveOptions {
            spill_dir: Some(dir.clone()),
            spill_threshold: 0.4,
            ..Default::default()
        },
    )
    .solve();
    assert_eq!(plain.log_score.to_bits(), spilled.log_score.to_bits());
    assert_eq!(plain.network, spilled.network);
    assert!(spilled.stats.spilled_bytes > 0);
    let _ = std::fs::remove_dir_all(&dir);
}
