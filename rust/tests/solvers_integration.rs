//! Cross-module integration: solvers × engines × scores × networks.

use bnsl::bn::{cpdag_of, repo, shd_cpdag};
use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::{LocalScorer, ScoreKind};
use bnsl::search::{hill_climb, HillClimbOptions};
use bnsl::solver::{
    brute, CancelToken, LeveledSolver, SilanderSolver, SolveOptions, StreamingSolver,
};
use bnsl::util::check::Check;
use bnsl::util::rng::Rng;

/// The central correctness triangle at a non-trivial size: leveled ==
/// silander == (for tiny p) brute force, across scores and datasets.
#[test]
fn solver_triangle_on_random_instances() {
    Check::new("triangle leveled/silander/brute")
        .cases(12)
        .run(|g| {
            let p = 3 + g.rng.below_usize(3); // 3..=5
            let n = 15 + g.rng.below_usize(100);
            let kinds = [
                ScoreKind::Jeffreys,
                ScoreKind::JeffreysObserved,
                ScoreKind::Bdeu { ess: 2.0 },
                ScoreKind::Bic,
                ScoreKind::Aic,
            ];
            let kind = kinds[g.rng.below_usize(kinds.len())];
            let d = synth::random(p, n, 4, &mut g.rng);
            let e = NativeEngine::new(&d, kind);
            let a = LeveledSolver::new(&e).solve();
            let b = SilanderSolver::new(&e).solve();
            let c = brute::best_dag_score(&d, kind);
            g.assert_close(a.log_score, b.log_score, 1e-12, "leveled == silander");
            g.assert_close(a.log_score, c, 1e-9, "leveled == brute");
        });
}

#[test]
fn asia_structure_recovery_at_scale() {
    // With enough data the exact solver must recover ASIA's equivalence
    // class almost perfectly (the deterministic 'either' node keeps this
    // interesting).
    let truth = repo::asia();
    let data = truth.sample(5000, 3);
    let e = NativeEngine::new(&data, ScoreKind::Jeffreys);
    let r = LeveledSolver::new(&e).solve();
    let diff = shd_cpdag(&r.network, truth.dag());
    assert!(
        diff.total() <= 3,
        "ASIA at n=5000 should be nearly exact, SHD={} ({diff:?})",
        diff.total()
    );
}

#[test]
fn structure_recovery_does_not_degrade_with_more_data() {
    let truth = repo::sachs();
    let small = truth.sample(100, 5);
    let large = truth.sample(3000, 5);
    let es = NativeEngine::new(&small, ScoreKind::Jeffreys);
    let el = NativeEngine::new(&large, ScoreKind::Jeffreys);
    let rs = LeveledSolver::new(&es).solve();
    let rl = LeveledSolver::new(&el).solve();
    let ds = shd_cpdag(&rs.network, truth.dag()).total();
    let dl = shd_cpdag(&rl.network, truth.dag()).total();
    assert!(
        dl <= ds,
        "structure recovery must not degrade with 30x more data ({ds} -> {dl})"
    );
}

#[test]
fn hill_climbing_vs_exact_gap_is_nonnegative() {
    let truth = repo::sachs();
    let data = truth.sample(400, 9);
    let e = NativeEngine::new(&data, ScoreKind::Jeffreys);
    let exact = LeveledSolver::new(&e).solve();
    let hc = hill_climb(
        &data,
        ScoreKind::Jeffreys,
        &HillClimbOptions {
            restarts: 3,
            seed: 4,
            ..Default::default()
        },
    );
    assert!(hc.log_score <= exact.log_score + 1e-9);
    // HC should land close on this easy instance
    assert!(
        exact.log_score - hc.log_score < 20.0,
        "gap suspiciously large: {}",
        exact.log_score - hc.log_score
    );
}

#[test]
fn markov_equivalent_dags_score_identically_under_jeffreys() {
    // Eq. 7 satisfies Markov equivalence: score is a class invariant.
    Check::new("score is CPDAG-invariant").cases(30).run(|g| {
        let p = 3 + g.rng.below_usize(3);
        let n = 30 + g.rng.below_usize(80);
        let d = synth::random(p, n, 3, &mut g.rng);
        let mut scorer = LocalScorer::new(&d, ScoreKind::Jeffreys);
        // random DAG + covered-edge reversal = equivalent pair
        let mut order: Vec<usize> = (0..p).collect();
        g.rng.shuffle(&mut order);
        let mut dag = bnsl::bn::Dag::empty(p);
        for i in 0..p {
            for j in (i + 1)..p {
                if g.rng.chance(0.5) {
                    dag.add_edge_unchecked(order[i], order[j]);
                }
            }
        }
        let covered: Vec<(usize, usize)> = dag
            .edges()
            .into_iter()
            .filter(|&(u, v)| dag.parents(v) & !(1u64 << u) == dag.parents(u))
            .collect();
        if covered.is_empty() {
            return;
        }
        let (u, v) = covered[g.rng.below_usize(covered.len())];
        let mut parents = dag.parent_masks().to_vec();
        parents[v] &= !(1u64 << u);
        parents[u] |= 1 << v;
        let reversed = bnsl::bn::Dag::from_parents(parents);
        assert_eq!(cpdag_of(&dag), cpdag_of(&reversed), "sanity: equivalent");
        let s1 = scorer.network(dag.parent_masks());
        let s2 = scorer.network(reversed.parent_masks());
        g.assert_close(s1, s2, 1e-10, "equivalent DAGs, equal Jeffreys score");
    });
}

#[test]
fn bic_is_also_equivalence_invariant() {
    let d = synth::random(4, 80, 3, &mut Rng::new(8));
    let mut scorer = LocalScorer::new(&d, ScoreKind::Bic);
    let a = bnsl::bn::Dag::from_edges(4, &[(0, 1), (1, 2)]);
    let b = bnsl::bn::Dag::from_edges(4, &[(2, 1), (1, 0)]);
    let sa = scorer.network(a.parent_masks());
    let sb = scorer.network(b.parent_masks());
    assert!((sa - sb).abs() < 1e-10);
}

#[test]
fn deep_chain_order_recovery_multithreaded() {
    // strong chain: optimal skeleton must be the chain, threads on
    let d = synth::chain(10, 600, 0.97, 5);
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let r = LeveledSolver::with_options(
        &e,
        SolveOptions {
            threads: 2,
            ..Default::default()
        },
    )
    .solve();
    let skel = r.network.skeleton();
    let expected: Vec<(usize, usize)> = (0..9).map(|i| (i, i + 1)).collect();
    assert_eq!(skel, expected);
}

#[test]
fn solvers_handle_degenerate_data() {
    // all-constant columns: nothing should crash or produce NaN
    let d = bnsl::data::Dataset::new(
        (0..4).map(|i| format!("C{i}")).collect(),
        vec![2, 2, 2, 2],
        vec![vec![0; 20], vec![0; 20], vec![1; 20], vec![1; 20]],
    );
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let a = LeveledSolver::new(&e).solve();
    let b = SilanderSolver::new(&e).solve();
    assert!(a.log_score.is_finite());
    assert_eq!(a.log_score.to_bits(), b.log_score.to_bits());
}

#[test]
fn n_equals_one_sample() {
    let d = bnsl::data::Dataset::new(
        vec!["A".into(), "B".into()],
        vec![2, 3],
        vec![vec![1], vec![2]],
    );
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let r = LeveledSolver::new(&e).solve();
    assert!(r.log_score.is_finite());
    // One sample cannot justify edges: mathematically the with-edge and
    // empty networks tie exactly (Eq. 7), and f64 potential differences
    // can break the tie by ~1e-15 either way. Assert the *score* carries
    // no edge support rather than the arbitrary tie winner.
    let mut s = LocalScorer::new(&d, ScoreKind::Jeffreys);
    let empty = s.network(&vec![0u64; 2]);
    assert!((r.log_score - empty).abs() < 1e-9, "edges gained real score");
}

#[test]
fn high_arity_variables() {
    let mut rng = Rng::new(77);
    let d = synth::random(5, 150, 12, &mut rng);
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let a = LeveledSolver::new(&e).solve();
    let b = SilanderSolver::new(&e).solve();
    assert_eq!(a.log_score.to_bits(), b.log_score.to_bits());
}

/// The streaming engine's acceptance check (ISSUE 6) at non-trivial
/// sizes: the frontier-only single-pass solver must reproduce the
/// resident `LeveledSolver` bit for bit — optimum, DAG, order and eval
/// counters — at p = 12..14 on both mask widths, while its own peak
/// accounting stays strictly below the resident solver's.
#[test]
fn streaming_is_bit_identical_to_leveled_at_p12_to_14_both_widths() {
    for (p, seed) in [(12usize, 121u64), (13, 131), (14, 141)] {
        let d = synth::binary(p, 90, seed);
        let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
        let resident = LeveledSolver::new(&e).solve();
        let narrow = StreamingSolver::new(&e).solve();
        let wide = StreamingSolver::<u64>::new_generic(&e).solve();
        for (label, r) in [("narrow", &narrow), ("wide", &wide)] {
            assert_eq!(
                resident.log_score.to_bits(),
                r.log_score.to_bits(),
                "p={p}: {label} streaming optimum drifted from leveled"
            );
            assert_eq!(resident.network, r.network, "p={p}: {label} DAG differs");
            assert_eq!(resident.order, r.order, "p={p}: {label} order differs");
            assert_eq!(
                resident.stats.score_evals, r.stats.score_evals,
                "p={p}: {label} eval count differs"
            );
        }
        assert!(
            narrow.stats.peak_state_bytes < resident.stats.peak_state_bytes,
            "p={p}: streaming peak ({}) must undercut resident ({})",
            narrow.stats.peak_state_bytes,
            resident.stats.peak_state_bytes
        );
    }
}

/// Multithreaded streaming at p = 13 reproduces the single-thread
/// result exactly (the range splits are deterministic and the reduction
/// order is fixed, so bit-identity holds with threads on).
#[test]
fn streaming_multithreaded_matches_sequential_at_p13() {
    let d = synth::binary(13, 70, 2026);
    let e = NativeEngine::new(&d, ScoreKind::Bdeu { ess: 1.0 });
    let seq = StreamingSolver::new(&e).solve();
    let par = StreamingSolver::with_options(
        &e,
        SolveOptions {
            threads: 3,
            ..Default::default()
        },
    )
    .solve();
    assert_eq!(seq.log_score.to_bits(), par.log_score.to_bits());
    assert_eq!(seq.network, par.network);
}

/// Cancellation trade at integration scale: a pre-fired token makes
/// `try_solve` return `None` at the first level boundary with nothing
/// durable behind it — streaming has no checkpoint, so the *same*
/// solver re-runs from scratch and still lands on the exact optimum.
#[test]
fn cancelled_streaming_rerun_from_scratch_is_exact() {
    let d = synth::binary(12, 60, 909);
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let cancel = CancelToken::new();
    cancel.cancel();
    let solver = StreamingSolver::with_options(
        &e,
        SolveOptions {
            cancel: cancel.clone(),
            ..Default::default()
        },
    );
    assert!(solver.try_solve().is_none(), "fired token must abort");

    // no resume artifact exists by construction; re-running means a
    // fresh solver with a fresh token, from level 0
    let rerun = StreamingSolver::new(&e)
        .try_solve()
        .expect("un-cancelled run must complete");
    let resident = LeveledSolver::new(&e).solve();
    assert_eq!(resident.log_score.to_bits(), rerun.log_score.to_bits());
}

#[test]
fn duplicate_columns_tie_handling() {
    // identical columns create score ties between (u→v) and (v→u);
    // solvers must stay consistent with each other and finite
    let col = vec![0u8, 1, 0, 1, 1, 0, 1, 0, 0, 1];
    let d = bnsl::data::Dataset::new(
        vec!["A".into(), "B".into(), "C".into()],
        vec![2, 2, 2],
        vec![col.clone(), col.clone(), col],
    );
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let a = LeveledSolver::new(&e).solve();
    let b = SilanderSolver::new(&e).solve();
    assert_eq!(a.log_score.to_bits(), b.log_score.to_bits());
}
