//! Integration tests for the evaluation harness (ISSUE 7): the committed
//! `.bif` fixtures against the embedded networks, sampler properties,
//! `.jaa` interop through real files, and the dataset-vs-score-table
//! bit-identity guarantee at both mask widths.

use bnsl::bn::{repo, shd_cpdag, Dag};
use bnsl::data::Dataset;
use bnsl::engine::{NativeEngine, ScoreTable, TableEngine};
use bnsl::eval::{bif, edge_metrics, edge_metrics_cpdag, jaa};
use bnsl::score::ScoreKind;
use bnsl::solver::LeveledSolver;
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("examples/networks")
        .join(name)
}

/// Satellite (ISSUE 7): the committed asia fixture IS the embedded
/// network — names, arities, structure and every CPT literal, bit for
/// bit. Equal CPTs imply equal joint log-probabilities; over all 2^8
/// joint states that is a complete comparison.
#[test]
fn asia_bif_golden_matches_embedded_network() {
    let parsed = bif::read_bif(&fixture("asia.bif")).unwrap();
    let embedded = repo::asia();
    assert_eq!(parsed.names(), embedded.names());
    assert_eq!(parsed.arities(), embedded.arities());
    assert_eq!(parsed.dag().edges(), embedded.dag().edges());
    let mut sample = [0u8; 8];
    for code in 0..(1u16 << 8) {
        for (x, s) in sample.iter_mut().enumerate() {
            *s = ((code >> x) & 1) as u8;
        }
        assert_eq!(
            parsed.log_prob(&sample).to_bits(),
            embedded.log_prob(&sample).to_bits(),
            "joint state {code:#010b}"
        );
    }
    // and therefore identical seeded samples
    assert_eq!(parsed.sample(200, 7), embedded.sample(200, 7));
}

/// Satellite (ISSUE 7): the CHILD fixture carries the published shape —
/// 20 nodes, 25 arcs, published arities — and is a well-formed DAG.
#[test]
fn child_bif_has_the_published_shape() {
    let net = bif::read_bif(&fixture("child.bif")).unwrap();
    assert_eq!(net.p(), 20);
    assert_eq!(net.dag().edge_count(), 25);
    assert_eq!(
        net.arities(),
        &[2, 6, 3, 2, 3, 4, 3, 3, 2, 2, 3, 3, 5, 2, 2, 3, 3, 2, 5, 2]
    );
    assert!(net.dag().topological_order().is_some());
    let idx = |name: &str| {
        net.names()
            .iter()
            .position(|n| n == name)
            .unwrap_or_else(|| panic!("{name} missing"))
    };
    for (a, b) in [
        ("BirthAsphyxia", "Disease"),
        ("Disease", "LungParench"),
        ("LungParench", "ChestXray"),
        ("ChestXray", "XrayReport"),
        ("HypoxiaInO2", "LowerBodyO2"),
    ] {
        assert!(net.dag().has_edge(idx(a), idx(b)), "{a} -> {b} missing");
    }
}

/// Satellite (ISSUE 8): the INSURANCE fixture carries the published
/// shape — 27 nodes, 52 arcs, published arities — and is a well-formed
/// DAG. At 27 variables it is the largest committed fixture, sized for
/// anytime/approximate work where exact solves are out of reach.
#[test]
fn insurance_bif_has_the_published_shape() {
    let net = bif::read_bif(&fixture("insurance.bif")).unwrap();
    assert_eq!(net.p(), 27);
    assert_eq!(net.dag().edge_count(), 52);
    assert_eq!(
        net.arities(),
        &[
            3, 4, 4, 2, 4, 2, 2, 5, 2, 4, 2, 3, 3, 3, 3, 2, 2, 5, 4, 4, 4,
            2, 4, 4, 4, 4, 4
        ]
    );
    assert!(net.dag().topological_order().is_some());
    let idx = |name: &str| {
        net.names()
            .iter()
            .position(|n| n == name)
            .unwrap_or_else(|| panic!("{name} missing"))
    };
    for (a, b) in [
        ("Age", "SocioEcon"),
        ("SocioEcon", "MakeModel"),
        ("MakeModel", "CarValue"),
        ("CarValue", "Theft"),
        ("Theft", "ThisCarCost"),
        ("ThisCarCost", "PropCost"),
        ("Accident", "MedCost"),
    ] {
        assert!(net.dag().has_edge(idx(a), idx(b)), "{a} -> {b} missing");
    }
}

/// Satellite (ISSUE 9): the ALARM fixture carries the published shape —
/// 37 nodes, 46 arcs, the embedded repo's (name → arity) map and every
/// published arc — and at p = 37 exceeds every exact cap (30 narrow /
/// 32 streaming / 34 wide / 36 sharded): it is the zoo's search-tier
/// workload.
#[test]
fn alarm_bif_has_the_published_shape() {
    let net = bif::read_bif(&fixture("alarm.bif")).unwrap();
    assert_eq!(net.p(), 37);
    assert_eq!(net.dag().edge_count(), 46);
    assert!(
        net.p() > bnsl::MAX_VARS_SHARDED,
        "alarm must exceed the largest exact cap to exercise the search tier"
    );
    assert!(net.dag().topological_order().is_some());
    let idx = |name: &str| {
        net.names()
            .iter()
            .position(|n| n == name)
            .unwrap_or_else(|| panic!("{name} missing"))
    };
    // the declaration order is topological, not bnlearn's, so compare
    // arities through the name map
    for (name, arity) in repo::ALARM_NAMES.iter().zip(repo::ALARM_ARITIES) {
        assert_eq!(net.arities()[idx(name)], arity, "{name} arity");
    }
    for (a, b) in repo::ALARM_EDGES {
        assert!(net.dag().has_edge(idx(a), idx(b)), "{a} -> {b} missing");
    }
    // seeded sampling works at full width — the search tier's input
    let d = net.sample(50, 1);
    assert_eq!((d.p(), d.n()), (37, 50));
}

/// Satellite (ISSUE 7, sampler properties): same seed → identical
/// dataset, different seed → different dataset, and the dataset's
/// column order / names / arities follow the `.bif` declaration.
#[test]
fn sampler_is_deterministic_and_declaration_shaped() {
    let net = bif::read_bif(&fixture("asia.bif")).unwrap();
    let d = net.sample(1000, 11);
    assert_eq!(net.sample(1000, 11), d);
    assert_ne!(net.sample(1000, 12), d);
    assert_eq!(d.n(), 1000);
    assert_eq!(
        d.names(),
        &["asia", "tub", "smoke", "lung", "bronc", "either", "xray", "dysp"]
            .map(String::from)
    );
    assert_eq!(d.arities(), net.arities());
    for i in 0..d.n() {
        for v in 0..d.p() {
            assert!(d.value(i, v) < net.arities()[v]);
        }
    }
}

/// Satellite (ISSUE 7, sampler properties): root marginals converge to
/// the CPT priors at large n (law of large numbers; the tolerances are
/// ~6 sigma, so a correct sampler virtually never trips them).
#[test]
fn root_marginals_converge_to_cpt_priors() {
    let net = bif::read_bif(&fixture("asia.bif")).unwrap();
    let n = 20_000;
    let d = net.sample(n, 9);
    let frac_yes = |v: usize| -> f64 {
        (0..n).filter(|&i| d.value(i, v) == 1).count() as f64 / n as f64
    };
    // smoke ~ Bernoulli(0.5): sigma = 0.0035
    assert!((frac_yes(2) - 0.5).abs() < 0.022, "smoke {}", frac_yes(2));
    // asia ~ Bernoulli(0.01): sigma = 0.0007
    assert!((frac_yes(0) - 0.01).abs() < 0.0045, "asia {}", frac_yes(0));
}

/// Tentpole (ISSUE 7): `.jaa` export → file → import → export is
/// byte-stable, and the imported table solves bit-identically to the
/// dataset it came from — on the narrow AND the wide mask path.
#[test]
fn jaa_file_roundtrip_solves_bit_identically_at_both_widths() {
    let net = repo::asia();
    let data = net.sample(600, 3);
    let table = ScoreTable::compute(&data, ScoreKind::Jeffreys);
    let text = jaa::export_jaa(&table);

    let dir = std::env::temp_dir().join(format!("bnsl_eval_jaa_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("asia.jaa");
    std::fs::write(&path, &text).unwrap();
    let imported = jaa::read_jaa(&path).unwrap();
    assert_eq!(jaa::export_jaa(&imported), text, "roundtrip byte-stable");
    assert_eq!(imported.fingerprint(), table.fingerprint());

    let native = NativeEngine::new(&data, ScoreKind::Jeffreys);
    let from_table = TableEngine::new(&imported);
    let a32 = LeveledSolver::new(&native).solve();
    let b32 = LeveledSolver::new(&from_table).solve();
    assert_eq!(a32.log_score.to_bits(), b32.log_score.to_bits());
    assert_eq!(a32.network, b32.network);
    assert_eq!(a32.order, b32.order);
    let a64 = LeveledSolver::<u64>::new_generic(&native).solve();
    let b64 = LeveledSolver::<u64>::new_generic(&from_table).solve();
    assert_eq!(a64.log_score.to_bits(), b64.log_score.to_bits());
    assert_eq!(a64.network, b64.network);
    assert_eq!(a32.log_score.to_bits(), a64.log_score.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite (ISSUE 7, metrics): hand-computed confusion counts on a
/// small fixture, and a Markov-equivalent pair scoring SHD 0 / F1 1
/// under CPDAG comparison while the directed comparison charges it.
#[test]
fn metrics_agree_with_hand_computed_fixtures() {
    // truth: 0->1, 1->2   learned: 0->1, 2->1, 0->3
    let truth = Dag::from_edges(4, &[(0, 1), (1, 2)]);
    let learned = Dag::from_edges(4, &[(0, 1), (2, 1), (0, 3)]);
    let m = edge_metrics(&learned, &truth);
    assert_eq!((m.tp, m.fp, m.fn_), (1, 2, 1));
    assert!((m.precision() - 1.0 / 3.0).abs() < 1e-12);
    assert!((m.recall() - 0.5).abs() < 1e-12);

    // chain vs reversed chain: same skeleton, no v-structures — Markov
    // equivalent, so CPDAG comparison is perfect
    let chain = Dag::from_edges(3, &[(0, 1), (1, 2)]);
    let reversed = Dag::from_edges(3, &[(2, 1), (1, 0)]);
    assert_eq!(shd_cpdag(&reversed, &chain).total(), 0);
    let mc = edge_metrics_cpdag(&reversed, &chain);
    assert_eq!((mc.tp, mc.fp, mc.fn_), (2, 0, 0));
    assert!((mc.f1() - 1.0).abs() < 1e-12);
    // the directed comparison must NOT call them equal
    assert!(edge_metrics(&reversed, &chain).tp == 0);
}

/// Tentpole (ISSUE 7): learning CHILD data from an exported score table
/// matches the dataset-backed solve — the interop path is exercised on
/// a fixture with non-binary arities, loaded from the committed file.
#[test]
fn child_fixture_scores_solve_matches_dataset_solve() {
    let net = bif::read_bif(&fixture("child.bif")).unwrap();
    let full = net.sample(400, 21);
    // restrict to the first 12 variables to keep the exact solve quick
    let p = 12;
    let data = Dataset::new(
        full.names()[..p].to_vec(),
        full.arities()[..p].to_vec(),
        (0..p)
            .map(|v| (0..full.n()).map(|i| full.value(i, v)).collect())
            .collect(),
    );
    let table = ScoreTable::compute(&data, ScoreKind::Bdeu { ess: 1.0 });
    let imported = jaa::parse_jaa(&jaa::export_jaa(&table)).unwrap();
    let native = NativeEngine::new(&data, ScoreKind::Bdeu { ess: 1.0 });
    let engine = TableEngine::new(&imported);
    let a = LeveledSolver::new(&native).solve();
    let b = LeveledSolver::new(&engine).solve();
    assert_eq!(a.log_score.to_bits(), b.log_score.to_bits());
    assert_eq!(a.network, b.network);
}
