//! Sharded coordinator integration: bit-identical sharded solves,
//! kill-and-restart resume at every level boundary, and corruption
//! diagnostics (ISSUE 2 satellite + acceptance coverage).

use bnsl::coordinator::shard::ShardOptions;
use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::solver::{solve_sharded, LeveledSolver, ShardOutcome, SolveResult};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bnsl_shard_resume_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn opts(dir: &PathBuf, shards: usize) -> ShardOptions {
    ShardOptions {
        shards,
        dir: dir.clone(),
        ..Default::default()
    }
}

fn complete(outcome: ShardOutcome) -> SolveResult {
    match outcome {
        ShardOutcome::Complete(r) => r,
        ShardOutcome::Checkpointed { level, .. } => {
            panic!("expected a finished solve, got a checkpoint at level {level}")
        }
    }
}

/// Sharded == unsharded, bit for bit: same enumeration order, same
/// tie-breaks, same reconstruction — across shard counts, including
/// shard counts exceeding some level sizes.
#[test]
fn sharded_solve_is_bit_identical_to_unsharded() {
    let d = synth::random(11, 90, 3, &mut bnsl::util::rng::Rng::new(77));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let plain = LeveledSolver::new(&e).solve();
    for shards in [1usize, 2, 4, 16] {
        let dir = tmpdir(&format!("bitident{shards}"));
        let r = complete(solve_sharded::<u32>(&e, &opts(&dir, shards)).unwrap());
        assert_eq!(
            plain.log_score.to_bits(),
            r.log_score.to_bits(),
            "shards={shards}: bit-identical optimum"
        );
        assert_eq!(plain.network, r.network, "shards={shards}");
        assert_eq!(plain.order, r.order, "shards={shards}");
        // one score eval per subset, exactly like the resident sweep
        assert_eq!(plain.stats.score_evals, r.stats.score_evals);
        assert_eq!(plain.stats.bps_updates, r.stats.bps_updates);
        assert!(r.stats.spilled_bytes > 0, "frontier actually streamed");
        assert_eq!(r.stats.resumed_levels, 0, "fresh run");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Wide (u64) sharded path agrees with the narrow sharded path bit for
/// bit on a narrow-sized instance.
#[test]
fn wide_sharded_matches_narrow_sharded() {
    let d = synth::random(9, 60, 3, &mut bnsl::util::rng::Rng::new(5));
    let e = NativeEngine::new(&d, ScoreKind::Bic);
    let dn = tmpdir("narrow_w");
    let dw = tmpdir("wide_w");
    let narrow = complete(solve_sharded::<u32>(&e, &opts(&dn, 4)).unwrap());
    let wide = complete(solve_sharded::<u64>(&e, &opts(&dw, 4)).unwrap());
    assert_eq!(narrow.log_score.to_bits(), wide.log_score.to_bits());
    assert_eq!(narrow.network, wide.network);
    let _ = std::fs::remove_dir_all(&dn);
    let _ = std::fs::remove_dir_all(&dw);
}

/// The resume acceptance criterion: interrupt a p = 12 sharded solve at
/// **every** level boundary, resume it, and require the resumed result
/// to be bit-identical to the uninterrupted run — with no completed
/// level recomputed (score-eval accounting proves it).
#[test]
fn resume_at_every_level_boundary_is_bit_identical_and_recomputes_nothing() {
    let p = 12;
    let d = synth::random(p, 80, 3, &mut bnsl::util::rng::Rng::new(2024));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let baseline = LeveledSolver::new(&e).solve();
    // C(p, k) for the no-recompute accounting
    let binom = |k: usize| -> u64 {
        let mut c = 1u64;
        for i in 0..k {
            c = c * (p as u64 - i as u64) / (i as u64 + 1);
        }
        c
    };
    for stop in 0..p {
        let dir = tmpdir(&format!("boundary{stop}"));
        let interrupted = solve_sharded::<u32>(
            &e,
            &ShardOptions {
                shards: 4,
                dir: dir.clone(),
                stop_after_level: Some(stop),
                ..Default::default()
            },
        )
        .unwrap();
        match interrupted {
            ShardOutcome::Checkpointed { level, .. } => assert_eq!(level, stop),
            ShardOutcome::Complete(_) => panic!("stop={stop}: expected a checkpoint"),
        }
        // resume with shards read back from the manifest (shards: 0)
        let resumed = complete(
            solve_sharded::<u32>(
                &e,
                &ShardOptions {
                    shards: 0,
                    dir: dir.clone(),
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        assert_eq!(
            baseline.log_score.to_bits(),
            resumed.log_score.to_bits(),
            "stop={stop}: bit-identical optimum after resume"
        );
        assert_eq!(baseline.network, resumed.network, "stop={stop}");
        assert_eq!(baseline.order, resumed.order, "stop={stop}");
        assert_eq!(
            resumed.stats.resumed_levels,
            stop as u32 + 1,
            "stop={stop}: levels 0..={stop} reused from disk"
        );
        // no recomputation: the resumed run scores exactly the subsets
        // of the levels it actually computed
        let expected_evals: u64 = (stop + 1..=p).map(binom).sum();
        assert_eq!(
            resumed.stats.score_evals, expected_evals,
            "stop={stop}: completed levels were not rescored"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A corrupted shard header surfaces as a clean error naming the file,
/// not as a junk network or a panic.
#[test]
fn corrupt_shard_header_fails_cleanly_naming_the_file() {
    let d = synth::random(10, 60, 3, &mut bnsl::util::rng::Rng::new(9));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let dir = tmpdir("corrupt");
    let outcome = solve_sharded::<u32>(
        &e,
        &ShardOptions {
            shards: 2,
            dir: dir.clone(),
            stop_after_level: Some(3),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(matches!(outcome, ShardOutcome::Checkpointed { level: 3, .. }));
    // flip one byte in the magic of level 3, shard 1's .bps file — the
    // level the resume must read first
    let victim = dir.join("level_03_shard_0001.bps");
    let mut bytes = std::fs::read(&victim).expect("checkpoint left level-3 files");
    bytes[3] ^= 0x40;
    std::fs::write(&victim, &bytes).unwrap();
    let err = solve_sharded::<u32>(
        &e,
        &ShardOptions {
            shards: 0,
            dir: dir.clone(),
            ..Default::default()
        },
    )
    .unwrap_err()
    .to_string();
    assert!(
        err.contains("level_03_shard_0001.bps"),
        "error names the corrupt file: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Resuming against different data or a different score is rejected by
/// fingerprint, naming the mismatch.
#[test]
fn resume_with_wrong_data_or_score_is_rejected() {
    let d1 = synth::random(8, 50, 3, &mut bnsl::util::rng::Rng::new(1));
    let d2 = synth::random(8, 50, 3, &mut bnsl::util::rng::Rng::new(2));
    let e1 = NativeEngine::new(&d1, ScoreKind::Jeffreys);
    let dir = tmpdir("fingerprint");
    let _ = solve_sharded::<u32>(
        &e1,
        &ShardOptions {
            shards: 2,
            dir: dir.clone(),
            stop_after_level: Some(2),
            ..Default::default()
        },
    )
    .unwrap();
    let e2 = NativeEngine::new(&d2, ScoreKind::Jeffreys);
    let err = solve_sharded::<u32>(&e2, &opts(&dir, 0)).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "{err}");
    let e3 = NativeEngine::new(&d1, ScoreKind::Bic);
    let err = solve_sharded::<u32>(&e3, &opts(&dir, 0)).unwrap_err().to_string();
    assert!(err.contains("score"), "{err}");
    // the matching engine still resumes fine
    let r = complete(solve_sharded::<u32>(&e1, &opts(&dir, 0)).unwrap());
    let plain = LeveledSolver::new(&e1).solve();
    assert_eq!(plain.log_score.to_bits(), r.log_score.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Resuming an already-finished run recomputes nothing at all: the
/// result is reconstructed from the committed shard files.
#[test]
fn resume_of_finished_run_recomputes_nothing() {
    let d = synth::random(9, 70, 3, &mut bnsl::util::rng::Rng::new(3));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let dir = tmpdir("finished");
    let first = complete(solve_sharded::<u32>(&e, &opts(&dir, 2)).unwrap());
    let again = complete(solve_sharded::<u32>(&e, &opts(&dir, 0)).unwrap());
    assert_eq!(first.log_score.to_bits(), again.log_score.to_bits());
    assert_eq!(first.network, again.network);
    assert_eq!(again.stats.score_evals, 0, "no subset rescored");
    assert_eq!(again.stats.resumed_levels, 10, "all p+1 levels reused");
    let _ = std::fs::remove_dir_all(&dir);
}

/// CLI round trip: `learn --shards 2 --stop-after-level K` checkpoints,
/// `learn --resume DIR` finishes with the same result as a plain solve.
#[test]
fn cli_shards_and_resume_roundtrip() {
    let base = tmpdir("cli");
    std::fs::create_dir_all(&base).unwrap();
    let shard_dir = base.join("run");
    let out = base.join("net.json");
    bnsl::cli::run(vec![
        "learn".into(),
        "--network".into(),
        "asia".into(),
        "--n".into(),
        "120".into(),
        "--shards".into(),
        "2".into(),
        "--shard-dir".into(),
        shard_dir.to_string_lossy().into_owned(),
        "--stop-after-level".into(),
        "4".into(),
    ])
    .unwrap();
    assert!(shard_dir.join("manifest.json").exists(), "checkpoint committed");
    assert!(!out.exists(), "checkpointed run emits no network");
    bnsl::cli::run(vec![
        "learn".into(),
        "--network".into(),
        "asia".into(),
        "--n".into(),
        "120".into(),
        "--resume".into(),
        shard_dir.to_string_lossy().into_owned(),
        "--out".into(),
        out.to_string_lossy().into_owned(),
    ])
    .unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.contains("\"log_score\""));
    assert!(text.contains("\"resumed_levels\": 5"), "{text}");
    let _ = std::fs::remove_dir_all(&base);
}

/// The acceptance-scale run (p = 20, --shards 4): bit-identical to the
/// unsharded solver. Minutes of native scoring — ignored by default,
/// mirroring the wide-mask p = 33 projection test.
#[test]
#[ignore = "p = 20 exact solve; run explicitly for the acceptance check"]
fn p20_four_shards_bit_identical_acceptance() {
    let d = synth::random(20, 120, 2, &mut bnsl::util::rng::Rng::new(42));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let plain = LeveledSolver::new(&e).solve();
    let dir = tmpdir("p20");
    let sharded = complete(solve_sharded::<u32>(&e, &opts(&dir, 4)).unwrap());
    assert_eq!(plain.log_score.to_bits(), sharded.log_score.to_bits());
    assert_eq!(plain.network, sharded.network);
    assert_eq!(plain.order, sharded.order);
    let _ = std::fs::remove_dir_all(&dir);
}
