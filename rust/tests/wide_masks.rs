//! Wide-mask (u64) pipeline integration: the `VarMask` refactor's
//! acceptance checks.
//!
//! * exact solves on projections of a p = 33 synthetic dataset, run on
//!   the forced-wide path with spill enabled, cross-checked bit-exactly
//!   against the narrow path, the Silander baseline, and (at p ≤ 5)
//!   the brute-force all-DAGs oracle;
//! * hill climbing end-to-end on a p = 48 synthetic dataset (parent
//!   masks with bits ≥ 32 — impossible before the refactor);
//! * the full p = 33 spilled exact solve as an `#[ignore]`d opt-in run
//!   (it needs ≳ 170 GB RAM for the 2^33 sink tables + mid-lattice
//!   frontier and many core-hours; the projections above exercise the
//!   identical code path at container scale).

use bnsl::data::{synth, Dataset};
use bnsl::engine::NativeEngine;
use bnsl::score::{LocalScorer, ScoreKind};
use bnsl::search::{hill_climb, HillClimbOptions};
use bnsl::solver::{brute, LeveledSolver, SilanderSolver, SolveOptions};
use bnsl::util::rng::Rng;

fn p33_dataset() -> Dataset {
    let mut rng = Rng::new(3303);
    synth::random(33, 200, 3, &mut rng)
}

fn spill_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("bnsl_wide_{tag}_{}", std::process::id()))
}

#[test]
fn p33_projections_solve_identically_on_the_wide_spilled_path() {
    let data = p33_dataset();
    assert_eq!(data.p(), 33);
    // Three 10-variable projections, deliberately including indices ≥ 30
    // (beyond the narrow exact cap in the original ordering).
    let projections: [&[usize]; 3] = [
        &[32, 30, 28, 5, 0, 17, 22, 9, 14, 31],
        &[1, 3, 32, 8, 13, 21, 29, 30, 18, 27],
        &[6, 11, 2, 25, 31, 4, 19, 24, 10, 16],
    ];
    let dir = spill_dir("proj");
    for (i, proj) in projections.iter().enumerate() {
        let d = data.select_vars(proj);
        let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
        let narrow = LeveledSolver::new(&e).solve();
        let wide = LeveledSolver::<u64>::with_options_generic(
            &e,
            SolveOptions {
                spill_dir: Some(dir.clone()),
                spill_threshold: 0.5,
                ..Default::default()
            },
        )
        .solve();
        let baseline = SilanderSolver::new(&e).solve();
        assert!(
            wide.stats.spilled_bytes > 0,
            "projection {i}: spill engaged on the wide path"
        );
        assert_eq!(
            narrow.log_score.to_bits(),
            wide.log_score.to_bits(),
            "projection {i}: wide+spill == narrow, bit-exact"
        );
        assert_eq!(
            baseline.log_score.to_bits(),
            wide.log_score.to_bits(),
            "projection {i}: wide+spill == Silander baseline"
        );
        assert_eq!(narrow.network, wide.network, "projection {i}: same DAG");

        // brute-force oracle on the first five projected variables
        let d5 = d.take_vars(5);
        let e5 = NativeEngine::new(&d5, ScoreKind::Jeffreys);
        let wide5 = LeveledSolver::<u64>::new_generic(&e5).solve();
        let best5 = brute::best_dag_score(&d5, ScoreKind::Jeffreys);
        assert!(
            (wide5.log_score - best5).abs() < 1e-9,
            "projection {i}: wide path matches the all-DAGs optimum"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hillclimb_runs_end_to_end_on_p48_synthetic() {
    // A 48-variable planted chain: parent masks need bits ≥ 32, which
    // the u32 search layer could not even represent.
    let d = synth::chain(48, 120, 0.9, 4807);
    let opts = HillClimbOptions {
        restarts: 0,
        seed: 7,
        ..Default::default()
    };
    let r = hill_climb(&d, ScoreKind::Jeffreys, &opts);

    let mut scorer = LocalScorer::new(&d, ScoreKind::Jeffreys);
    let empty = scorer.network(&vec![0u64; 48]);
    assert!(
        r.log_score > empty,
        "climbing must beat the empty graph on strongly-structured data"
    );
    let achieved = scorer.network(r.network.parent_masks());
    assert!(
        (achieved - r.log_score).abs() < 1e-6,
        "claimed {} vs achieved {achieved}",
        r.log_score
    );
    assert!(r.moves_taken > 0);
    assert!(
        r.network
            .edges()
            .iter()
            .any(|&(u, v)| u >= 32 || v >= 32),
        "structure found in the upper (bit ≥ 32) half of the mask"
    );
    // sanity: the result is a representable DAG over 48 nodes
    assert!(r.network.topological_order().is_some());
}

#[test]
fn wide_scorer_matches_narrow_on_shared_prefix() {
    // log Q over the first 10 variables must not depend on whether the
    // dataset carries 23 extra columns or on the mask width used.
    let data = p33_dataset();
    let d10 = data.take_vars(10);
    let mut wide = LocalScorer::new(&data, ScoreKind::Jeffreys);
    let mut narrow = LocalScorer::new(&d10, ScoreKind::Jeffreys);
    let mut state = 0xBEEFu64;
    for _ in 0..200 {
        state = bnsl::util::rng::splitmix64(&mut state);
        let mask = (state & 0x3FF) as u32; // subsets of the first 10 vars
        assert_eq!(
            narrow.log_q(mask).to_bits(),
            wide.log_q(mask as u64).to_bits(),
            "mask={mask:#b}"
        );
    }
}

/// The acceptance-criterion run at full scale. `2^33` subsets: the sink
/// tables alone are `9·2^33` ≈ 77 GB and the peak `q`/`r` frontier adds
/// `32·C(33,16)` ≈ 37 GB, so this only fits a large-memory host — run
/// explicitly with `cargo test -q --release -- --ignored p33_full`.
#[test]
#[ignore = "needs ≳ 170 GB RAM and many core-hours; projections cover the code path"]
fn p33_full_exact_solve_with_spill() {
    let data = p33_dataset();
    let e = NativeEngine::new(&data, ScoreKind::Jeffreys);
    let dir = spill_dir("full33");
    let r = LeveledSolver::<u64>::with_options_generic(
        &e,
        SolveOptions {
            spill_dir: Some(dir.clone()),
            spill_threshold: 0.5,
            threads: 1,
            ..Default::default()
        },
    )
    .solve();
    assert!(r.log_score.is_finite());
    assert!(r.stats.spilled_bytes > 0);
    assert_eq!(r.stats.score_evals, 1u64 << 33);
    let _ = std::fs::remove_dir_all(&dir);
}
