//! End-to-end tests of the job service (`bnsl serve`) — the ISSUE 5
//! acceptance criteria:
//!
//! * a served solve is **bit-identical** to a direct [`LeveledSolver`]
//!   run of the same dataset;
//! * two concurrent identical submissions run the solver **exactly
//!   once** (dedup by dataset/score fingerprint);
//! * a drained (SIGTERM-equivalent) server's in-flight job **resumes
//!   via the run manifest** on restart and completes with the identical
//!   score.
//!
//! All tests drive the real HTTP surface through the shipped client
//! ([`bnsl::service::client`]) against a `Server` on an ephemeral port.

use bnsl::coordinator::plan::Budgets;
use bnsl::coordinator::shard::ShardOptions;
use bnsl::data::{parse_csv, synth, Dataset};
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::service::{client, ServeOptions, Server, SubmitRequest};
use bnsl::solver::{solve_sharded, LeveledSolver, ShardOutcome, SolveResult};
use bnsl::util::json::Json;
use bnsl::util::rng::Rng;
use std::path::PathBuf;
use std::time::Duration;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bnsl_service_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// CSV text of a dataset — submissions are parsed from exactly these
/// bytes on the server, so the direct reference solves parse them too.
fn csv_text(data: &Dataset) -> String {
    let mut out = data.names().join(",");
    out.push('\n');
    for i in 0..data.n() {
        let row: Vec<String> = (0..data.p())
            .map(|v| data.value(i, v).to_string())
            .collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

fn serve(dir: &PathBuf, max_concurrent: usize) -> Server {
    Server::start(ServeOptions {
        port: 0, // ephemeral
        jobs_dir: dir.clone(),
        budgets: Budgets::unlimited(),
        max_concurrent,
        ..Default::default()
    })
    .expect("server starts")
}

fn inline_request(text: &str, shards: usize) -> SubmitRequest {
    SubmitRequest {
        csv: Some(text.to_string()),
        shards,
        ..Default::default()
    }
}

/// Direct reference solve over the same bytes a submission carries.
fn direct_solve(text: &str) -> SolveResult {
    let data = parse_csv(text).expect("reference parse");
    let engine = NativeEngine::new(&data, ScoreKind::Jeffreys);
    LeveledSolver::new(&engine).solve()
}

fn wait_done(addr: &str, id: &str) -> Json {
    let status = client::wait_terminal(
        addr,
        id,
        Duration::from_millis(25),
        Duration::from_secs(120),
    )
    .expect("job reaches a terminal state");
    assert_eq!(
        status.get("state").and_then(Json::as_str),
        Some("done"),
        "{status:?}"
    );
    status
}

/// Acceptance: a served p = 12 solve is bit-identical to the direct
/// resident run — log-score bits, network, and variable order.
#[test]
fn served_result_is_bit_identical_to_direct_leveled_run() {
    let dir = temp_dir("bitident");
    let data = synth::random(12, 150, 3, &mut Rng::new(2024));
    let text = csv_text(&data);
    let direct = direct_solve(&text);

    let server = serve(&dir, 1);
    let addr = server.addr().to_string();
    let sub = client::submit(&addr, &inline_request(&text, 2)).unwrap();
    assert!(!sub.deduped && !sub.cached);
    wait_done(&addr, &sub.id);
    let served = client::result(&addr, &sub.id).unwrap();

    let direct_doc = direct.to_json(parse_csv(&text).unwrap().names());
    let served_score = served.get("log_score").unwrap().as_f64().unwrap();
    assert_eq!(
        served_score.to_bits(),
        direct.log_score.to_bits(),
        "served score must be bit-identical"
    );
    assert_eq!(served.get("network"), direct_doc.get("network"));
    assert_eq!(served.get("order"), direct_doc.get("order"));

    server.drain();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: two concurrent identical submissions coalesce onto one
/// job and the solver runs exactly once.
#[test]
fn concurrent_identical_submissions_run_the_solver_once() {
    let dir = temp_dir("dedup");
    let data = synth::random(12, 120, 3, &mut Rng::new(7));
    let text = csv_text(&data);
    let server = serve(&dir, 2);
    let addr = server.addr().to_string();

    let ids: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                let req = inline_request(&text, 2);
                scope.spawn(move || client::submit(&addr, &req).unwrap().id)
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(ids[0], ids[1], "identical submissions share one job");
    wait_done(&addr, &ids[0]);

    // exactly-once: both the in-process counter and the stats endpoint
    assert_eq!(server.manager().solver_runs(), 1);
    let (code, stats) = client::request(&addr, "GET", "/v1/stats", None).unwrap();
    assert_eq!(code, 200);
    let stats = Json::parse(&stats).unwrap();
    assert_eq!(
        stats
            .get("counters")
            .unwrap()
            .get("solver_runs")
            .unwrap()
            .as_u64(),
        Some(1),
        "{stats:?}"
    );
    // and the result matches the direct run bit for bit
    let direct = direct_solve(&text);
    let served = client::result(&addr, &ids[0]).unwrap();
    assert_eq!(
        served.get("log_score").unwrap().as_f64().unwrap().to_bits(),
        direct.log_score.to_bits()
    );

    server.drain();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: server goes down with a job mid-run (manifest holds a
/// committed level prefix); the next server resumes it via the manifest
/// — not from scratch — and completes with the identical score.
#[test]
fn restart_resumes_the_inflight_job_via_the_manifest() {
    let dir = temp_dir("resume");
    let data = synth::random(13, 140, 3, &mut Rng::new(31));
    let text = csv_text(&data);
    let direct = direct_solve(&text);

    // server A accepts the job but has no executors — it goes down
    // before finishing (the deterministic stand-in for a SIGTERM that
    // landed mid-solve)
    let fingerprint;
    let id;
    {
        let server = serve(&dir, 0);
        let addr = server.addr().to_string();
        let sub = client::submit(&addr, &inline_request(&text, 2)).unwrap();
        id = sub.id.clone();
        let status = client::status(&addr, &sub.id).unwrap();
        fingerprint = status
            .get("fingerprint")
            .and_then(Json::as_str)
            .unwrap()
            .to_string();
        assert_eq!(status.get("state").and_then(Json::as_str), Some("queued"));
        server.drain();
        server.join().unwrap();
    }

    // the "mid-run" state: levels 0..=5 committed in the job's run dir,
    // exactly what a drain checkpoint leaves behind
    let parsed = parse_csv(&text).unwrap();
    let engine = NativeEngine::new(&parsed, ScoreKind::Jeffreys);
    let checkpoint = solve_sharded::<u32>(
        &engine,
        &ShardOptions {
            shards: 2,
            dir: dir.join("runs").join(&fingerprint),
            stop_after_level: Some(5),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(matches!(
        checkpoint,
        ShardOutcome::Checkpointed { level: 5, .. }
    ));

    // restart: recovery requeues the job; execution adopts the manifest
    let server = serve(&dir, 1);
    let addr = server.addr().to_string();
    wait_done(&addr, &id);
    let served = client::result(&addr, &id).unwrap();
    assert_eq!(
        served.get("log_score").unwrap().as_f64().unwrap().to_bits(),
        direct.log_score.to_bits(),
        "resumed solve is bit-identical to the direct run"
    );
    assert_eq!(
        served
            .get("stats")
            .unwrap()
            .get("resumed_levels")
            .unwrap()
            .as_u64(),
        Some(6),
        "levels 0..=5 were reused from the checkpoint, not recomputed"
    );
    // a repeat submission is now a pure cache hit on the same job
    let again = client::submit(&addr, &inline_request(&text, 2)).unwrap();
    assert!(again.deduped && again.cached);
    assert_eq!(again.id, id);

    server.drain();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Sum of one metric family's values across label variants in a
/// Prometheus-text body (`name{labels} value` / `name value` lines).
fn metric_sum(body: &str, family: &str) -> f64 {
    let mut total = 0.0;
    for line in body.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        if let Some((name, value)) = line.rsplit_once(' ') {
            if name.split('{').next().unwrap_or(name) == family {
                if let Ok(v) = value.parse::<f64>() {
                    total += v;
                }
            }
        }
    }
    total
}

/// Acceptance: `/v1/metrics` counters move across a real
/// submit → solve → result cycle. The registry is process-global and
/// other tests in this binary run concurrently, so everything is
/// asserted as deltas between two scrapes — only this test's own solve
/// is needed to make them strictly positive.
#[test]
fn metrics_counters_advance_across_a_real_solve() {
    let dir = temp_dir("metrics_cycle");
    let data = synth::random(12, 130, 3, &mut Rng::new(99));
    let text = csv_text(&data);
    let server = serve(&dir, 1);
    let addr = server.addr().to_string();

    let (code, before) = client::request(&addr, "GET", "/v1/metrics", None).unwrap();
    assert_eq!(code, 200);
    let levels_before = metric_sum(&before, "bnsl_solver_levels_completed_total");
    let evals_before = metric_sum(&before, "bnsl_solver_score_evals_total");
    let solves_before = metric_sum(&before, "bnsl_executor_solves_total");

    let sub = client::submit(&addr, &inline_request(&text, 2)).unwrap();
    wait_done(&addr, &sub.id);
    let served = client::result(&addr, &sub.id).unwrap();
    let direct = direct_solve(&text);
    assert_eq!(
        served.get("log_score").unwrap().as_f64().unwrap().to_bits(),
        direct.log_score.to_bits()
    );

    let (code, after) = client::request(&addr, "GET", "/v1/metrics", None).unwrap();
    assert_eq!(code, 200);
    // the scrape is well-formed Prometheus text with the service,
    // solver, and memtrack families all present
    assert!(
        after.contains("# TYPE bnsl_service_queue_depth gauge"),
        "{after}"
    );
    assert!(
        after.contains("# TYPE bnsl_http_request_seconds histogram"),
        "{after}"
    );
    assert!(after.contains("bnsl_memtrack_peak_bytes"), "{after}");
    assert!(
        after.contains("# TYPE bnsl_solver_levels_completed_total counter"),
        "{after}"
    );

    let levels_delta = metric_sum(&after, "bnsl_solver_levels_completed_total") - levels_before;
    let evals_delta = metric_sum(&after, "bnsl_solver_score_evals_total") - evals_before;
    let solves_delta = metric_sum(&after, "bnsl_executor_solves_total") - solves_before;
    assert!(levels_delta > 0.0, "solver level counter did not move");
    assert!(evals_delta > 0.0, "score-eval counter did not move");
    assert!(solves_delta >= 1.0, "executor solve counter did not move");

    server.drain();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: the admission verdict reaches the HTTP client on a 422.
#[test]
fn over_budget_submission_rejected_with_verdict_in_the_error_body() {
    let dir = temp_dir("reject");
    let server = Server::start(ServeOptions {
        port: 0,
        jobs_dir: dir.clone(),
        budgets: Budgets {
            ram_bytes: 1,
            ..Budgets::unlimited()
        },
        max_concurrent: 0,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().to_string();
    let data = synth::random(10, 60, 3, &mut Rng::new(5));
    let err = client::submit(&addr, &inline_request(&csv_text(&data), 4)).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("422"), "{text}");
    assert!(text.contains("\"fits\":false"), "{text}");
    assert!(text.contains("resident RAM"), "{text}");
    server.drain();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: cancel-then-resubmit over HTTP — the cancelled job stays
/// terminal, the resubmission is a fresh job that completes.
#[test]
fn cancel_then_resubmit_completes_over_http() {
    let dir = temp_dir("cancel");
    let data = synth::random(11, 100, 3, &mut Rng::new(17));
    let text = csv_text(&data);
    let (cancelled_id, resub_id);
    {
        // queue-only server: the job deterministically sits in `queued`
        let server = serve(&dir, 0);
        let addr = server.addr().to_string();
        let sub = client::submit(&addr, &inline_request(&text, 2)).unwrap();
        let response = client::cancel(&addr, &sub.id).unwrap();
        assert_eq!(response.get("state").and_then(Json::as_str), Some("cancelled"));
        // cancelling again: terminal conflict (409)
        let err = client::cancel(&addr, &sub.id).unwrap_err();
        assert!(format!("{err:#}").contains("409"), "{err:#}");
        // resubmit: a fresh job, not deduped onto the cancelled one
        let resub = client::submit(&addr, &inline_request(&text, 2)).unwrap();
        assert!(!resub.deduped);
        assert_ne!(resub.id, sub.id);
        cancelled_id = sub.id;
        resub_id = resub.id;
        server.drain();
        server.join().unwrap();
    }
    // a real executor picks the resubmission up after restart
    let server = serve(&dir, 1);
    let addr = server.addr().to_string();
    wait_done(&addr, &resub_id);
    let status = client::status(&addr, &cancelled_id).unwrap();
    assert_eq!(
        status.get("state").and_then(Json::as_str),
        Some("cancelled"),
        "cancelled job stays terminal across restarts"
    );
    let direct = direct_solve(&text);
    let served = client::result(&addr, &resub_id).unwrap();
    assert_eq!(
        served.get("log_score").unwrap().as_f64().unwrap().to_bits(),
        direct.log_score.to_bits()
    );
    server.drain();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
