//! Cluster coordinator integration (ISSUE 3): two in-process "hosts"
//! cooperating through one shared directory must reproduce the
//! single-process result bit for bit — across every level boundary, with
//! exactly-once work, after stale-claim reclaim of a "killed" host's
//! shard, and through the CLI. (The true multi-*process* SIGKILL path is
//! exercised end-to-end by `tools/cluster_smoke.sh` in the CI `cluster`
//! job.)

use bnsl::coordinator::cluster::ClusterOptions;
use bnsl::coordinator::shard::ShardOptions;
use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::solver::{solve_clustered, solve_sharded, LeveledSolver, ShardOutcome, SolveResult};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bnsl_cluster_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Host options for the tests: a long heartbeat (sub-second shards never
/// go stale under CI scheduling jitter) and a tight poll (barriers are
/// instant).
fn copts(dir: &Path, shards: usize, host_id: usize, stop: Option<usize>) -> ClusterOptions {
    ClusterOptions {
        shard: ShardOptions {
            shards,
            dir: dir.to_path_buf(),
            stop_after_level: stop,
            hosts: 2,
            ..Default::default()
        },
        host_id,
        heartbeat: Duration::from_secs(2),
        poll: Duration::from_millis(2),
    }
}

fn complete(outcome: ShardOutcome) -> SolveResult {
    match outcome {
        ShardOutcome::Complete(r) => r,
        ShardOutcome::Checkpointed { level, .. } => {
            panic!("expected a finished solve, got a checkpoint at level {level}")
        }
    }
}

/// Run `hosts` in-process cluster hosts to completion (threads standing
/// in for machines — the coordination surface is the filesystem either
/// way) and return their results in host order.
fn run_hosts(
    engine: &NativeEngine,
    dir: &Path,
    shards: usize,
    hosts: usize,
    stop: Option<usize>,
) -> Vec<ShardOutcome> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..hosts)
            .map(|host| {
                let opts = copts(dir, shards, host, stop);
                scope.spawn(move || solve_clustered::<u32>(engine, &opts).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Acceptance criterion: a two-host cluster solve over a shared
/// directory is bit-identical to the single-process solver, and the
/// claim ledger hands out every shard exactly once (work conservation:
/// the hosts' score-eval counts sum to exactly `2^p`).
#[test]
fn two_hosts_are_bit_identical_to_single_process_with_exactly_once_work() {
    let p = 11;
    let d = synth::random(p, 90, 3, &mut bnsl::util::rng::Rng::new(77));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let baseline = LeveledSolver::new(&e).solve();
    let dir = tmpdir("two_hosts");
    let outcomes = run_hosts(&e, &dir, 4, 2, None);
    let results: Vec<SolveResult> = outcomes.into_iter().map(complete).collect();
    for (host, r) in results.iter().enumerate() {
        assert_eq!(
            baseline.log_score.to_bits(),
            r.log_score.to_bits(),
            "host {host}: bit-identical optimum"
        );
        assert_eq!(baseline.network, r.network, "host {host}");
        assert_eq!(baseline.order, r.order, "host {host}");
    }
    let total_evals: u64 = results.iter().map(|r| r.stats.score_evals).sum();
    assert_eq!(
        total_evals,
        1u64 << p,
        "every subset scored exactly once across the cluster"
    );
    let total_bps: u64 = results.iter().map(|r| r.stats.bps_updates).sum();
    assert_eq!(total_bps, baseline.stats.bps_updates, "no shard re-run");
    assert!(
        results.iter().map(|r| r.stats.spilled_bytes).sum::<u64>() > 0,
        "the frontier actually streamed through shard files"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The boundary acceptance criterion: drive two in-process hosts through
/// **every** p = 12 level boundary — checkpoint the cluster at level K,
/// then bring two hosts back up to finish — and require the final result
/// to be bit-identical to the uninterrupted single-process run with no
/// committed level recomputed (work conservation per phase).
#[test]
fn two_hosts_resume_at_every_level_boundary_bit_identical() {
    let p = 12;
    let d = synth::random(p, 80, 3, &mut bnsl::util::rng::Rng::new(2024));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let baseline = LeveledSolver::new(&e).solve();
    let binom = |k: usize| -> u64 {
        let mut c = 1u64;
        for i in 0..k {
            c = c * (p as u64 - i as u64) / (i as u64 + 1);
        }
        c
    };
    for stop in 0..p {
        let dir = tmpdir(&format!("boundary{stop}"));
        // phase 1: both hosts stop at the boundary, durably committed
        for outcome in run_hosts(&e, &dir, 4, 2, Some(stop)) {
            match outcome {
                ShardOutcome::Checkpointed { level, .. } => assert_eq!(level, stop),
                ShardOutcome::Complete(_) => panic!("stop={stop}: expected a checkpoint"),
            }
        }
        // phase 2: a fresh pair of hosts joins the same directory
        let outcomes = run_hosts(&e, &dir, 4, 2, None);
        let results: Vec<SolveResult> = outcomes.into_iter().map(complete).collect();
        for r in &results {
            assert_eq!(
                baseline.log_score.to_bits(),
                r.log_score.to_bits(),
                "stop={stop}: bit-identical after cluster resume"
            );
            assert_eq!(baseline.network, r.network, "stop={stop}");
            assert_eq!(baseline.order, r.order, "stop={stop}");
            // ≥, not ==: a host that starts late may find levels beyond
            // the checkpoint already committed by its partner
            assert!(
                r.stats.resumed_levels >= stop as u32 + 1,
                "stop={stop}: committed levels reused, not recomputed (got {})",
                r.stats.resumed_levels
            );
        }
        let total: u64 = results.iter().map(|r| r.stats.score_evals).sum();
        let expected: u64 = (stop + 1..=p).map(binom).sum();
        assert_eq!(
            total, expected,
            "stop={stop}: the resumed cluster scored only the uncommitted levels"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A SIGKILLed host's leavings — a stale claim (dead heartbeat) and a
/// garbage staged shard file — must be reclaimed and overwritten: the
/// surviving host re-runs the orphaned shard and the result stays
/// bit-identical, with the ledger cleaned behind the commits.
#[test]
fn stale_claim_of_dead_host_is_reclaimed_and_rerun() {
    let p = 10;
    let d = synth::random(p, 70, 3, &mut bnsl::util::rng::Rng::new(9));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let baseline = LeveledSolver::new(&e).solve();
    let dir = tmpdir("reclaim");
    // a real cluster checkpoint at level 3…
    match solve_clustered::<u32>(&e, &copts(&dir, 2, 0, Some(3))).unwrap() {
        ShardOutcome::Checkpointed { level, .. } => assert_eq!(level, 3),
        ShardOutcome::Complete(_) => panic!("expected a checkpoint"),
    }
    // …then forge what a SIGKILLed host 9 would leave mid-level-4: a
    // claim whose heartbeat died an hour ago and a partial staged file
    let claim = dir.join("claim-04-0001.json");
    std::fs::write(
        &claim,
        "{\"format\": 1, \"level\": 4, \"shard\": 1, \"host\": 9, \
         \"pid\": 1, \"heartbeat_secs\": 2}",
    )
    .unwrap();
    let file = std::fs::File::options().write(true).open(&claim).unwrap();
    file.set_modified(std::time::SystemTime::now() - Duration::from_secs(3600))
        .unwrap();
    drop(file);
    let stray = dir.join("level_04_shard_0001.qr.host-0009-1");
    std::fs::write(&stray, b"partial garbage from a dead writer").unwrap();
    // the surviving host steals the stale claim, re-runs the shard, and
    // finishes bit-identically
    let r = complete(solve_clustered::<u32>(&e, &copts(&dir, 2, 0, None)).unwrap());
    assert_eq!(baseline.log_score.to_bits(), r.log_score.to_bits());
    assert_eq!(baseline.network, r.network);
    let expected: u64 = (4..=p as u64)
        .map(|k| {
            let mut c = 1u64;
            for i in 0..k {
                c = c * (p as u64 - i) / (i + 1);
            }
            c
        })
        .sum();
    assert_eq!(
        r.stats.score_evals, expected,
        "exactly the uncommitted levels were scored, orphaned shard included once"
    );
    // the steal remnant, forged claim and staged stray are all gone
    // (cleaned when their level's successor committed)
    assert!(!claim.exists(), "forged claim reclaimed");
    assert!(!stray.exists(), "staged stray cleaned");
    let leftovers: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("claim-") || n.contains(".stale-"))
        .collect();
    assert!(leftovers.is_empty(), "no claims survive the run: {leftovers:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The cluster writes ordinary sharded-run state: a single-host
/// `--resume` (no cluster) finishes a cluster checkpoint, and vice
/// versa a cluster host finishes a plain sharded checkpoint.
#[test]
fn cluster_and_plain_sharded_checkpoints_are_interchangeable() {
    let d = synth::random(9, 60, 3, &mut bnsl::util::rng::Rng::new(5));
    let e = NativeEngine::new(&d, ScoreKind::Bic);
    let baseline = LeveledSolver::new(&e).solve();
    // cluster checkpoint → plain sharded resume
    let dir_a = tmpdir("interop_a");
    match solve_clustered::<u32>(&e, &copts(&dir_a, 2, 0, Some(4))).unwrap() {
        ShardOutcome::Checkpointed { level, .. } => assert_eq!(level, 4),
        ShardOutcome::Complete(_) => panic!("expected a checkpoint"),
    }
    let resumed = match solve_sharded::<u32>(
        &e,
        &ShardOptions {
            shards: 0, // from the (v2) manifest
            dir: dir_a.clone(),
            ..Default::default()
        },
    )
    .unwrap()
    {
        ShardOutcome::Complete(r) => r,
        ShardOutcome::Checkpointed { level, .. } => panic!("checkpoint at {level}"),
    };
    assert_eq!(baseline.log_score.to_bits(), resumed.log_score.to_bits());
    // plain sharded checkpoint → cluster resume
    let dir_b = tmpdir("interop_b");
    let outcome = solve_sharded::<u32>(
        &e,
        &ShardOptions {
            shards: 2,
            dir: dir_b.clone(),
            stop_after_level: Some(2),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(matches!(outcome, ShardOutcome::Checkpointed { level: 2, .. }));
    let r = complete(solve_clustered::<u32>(&e, &copts(&dir_b, 2, 0, None)).unwrap());
    assert_eq!(baseline.log_score.to_bits(), r.log_score.to_bits());
    assert_eq!(baseline.network, r.network);
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// CLI wiring: `learn --cluster` drives the cluster coordinator end to
/// end (single host standing in for the pool) and emits the usual
/// result record.
#[test]
fn cli_cluster_roundtrip() {
    let base = tmpdir("cli");
    std::fs::create_dir_all(&base).unwrap();
    let shard_dir = base.join("run");
    let out = base.join("net.json");
    bnsl::cli::run(vec![
        "learn".into(),
        "--network".into(),
        "asia".into(),
        "--n".into(),
        "120".into(),
        "--cluster".into(),
        "--host-id".into(),
        "0".into(),
        "--hosts".into(),
        "1".into(),
        "--heartbeat-secs".into(),
        "2".into(),
        "--shards".into(),
        "2".into(),
        "--shard-dir".into(),
        shard_dir.to_string_lossy().into_owned(),
        "--out".into(),
        out.to_string_lossy().into_owned(),
    ])
    .unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.contains("\"log_score\""));
    let manifest = std::fs::read_to_string(shard_dir.join("manifest.json")).unwrap();
    assert!(manifest.contains("\"format\": 2"), "{manifest}");
    assert!(manifest.contains("\"hosts\": 1"), "{manifest}");
    // a conflicting --heartbeat-secs is rejected up front
    let err = bnsl::cli::run(vec![
        "learn".into(),
        "--network".into(),
        "asia".into(),
        "--n".into(),
        "40".into(),
        "--cluster".into(),
        "--heartbeat-secs".into(),
        "0".into(),
        "--shard-dir".into(),
        base.join("bad").to_string_lossy().into_owned(),
    ])
    .unwrap_err()
    .to_string();
    assert!(err.contains("heartbeat"), "{err}");
    let _ = std::fs::remove_dir_all(&base);
}
