//! Storage-backend matrix integration (ISSUE 4): the sharded and
//! clustered solvers must produce **byte-identical** results whether the
//! coordinator runs on the POSIX backend or the S3-semantics object
//! backend — including through a stale-claim reclaim injected into the
//! object run — and the CLI must wire `--backend` end to end. (The
//! multi-*process* kill-and-restart path runs in CI for both backends
//! via `tools/cluster_smoke.sh`.)

use bnsl::coordinator::cluster::ClusterOptions;
use bnsl::coordinator::shard::ShardOptions;
use bnsl::coordinator::storage::{
    BackendKind, ObjectBackend, ObjectFaults, StorageBackend,
};
use bnsl::data::synth;
use bnsl::engine::NativeEngine;
use bnsl::score::ScoreKind;
use bnsl::solver::{solve_clustered, solve_sharded, LeveledSolver, ShardOutcome, SolveResult};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bnsl_storage_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn copts(
    dir: &Path,
    backend: BackendKind,
    shards: usize,
    host_id: usize,
    stop: Option<usize>,
) -> ClusterOptions {
    ClusterOptions {
        shard: ShardOptions {
            shards,
            dir: dir.to_path_buf(),
            stop_after_level: stop,
            hosts: 2,
            backend,
            ..Default::default()
        },
        host_id,
        heartbeat: Duration::from_secs(2),
        poll: Duration::from_millis(2),
    }
}

fn complete(outcome: ShardOutcome) -> SolveResult {
    match outcome {
        ShardOutcome::Complete(r) => r,
        ShardOutcome::Checkpointed { level, .. } => {
            panic!("expected a finished solve, got a checkpoint at level {level}")
        }
    }
}

fn run_hosts(
    engine: &NativeEngine,
    dir: &Path,
    backend: BackendKind,
    shards: usize,
    hosts: usize,
    stop: Option<usize>,
) -> Vec<ShardOutcome> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..hosts)
            .map(|host| {
                let opts = copts(dir, backend, shards, host, stop);
                scope.spawn(move || solve_clustered::<u32>(engine, &opts).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

fn binom(p: u64, k: u64) -> u64 {
    let mut c = 1u64;
    for i in 0..k {
        c = c * (p - i) / (i + 1);
    }
    c
}

/// Single-host sharded solves agree bit for bit across backends and with
/// the resident solver, and an object-backend checkpoint resumes on the
/// object backend.
#[test]
fn sharded_solve_is_bit_identical_across_backends() {
    let p = 10;
    let d = synth::random(p, 70, 3, &mut bnsl::util::rng::Rng::new(31));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let baseline = LeveledSolver::new(&e).solve();
    let mut results = Vec::new();
    for backend in [BackendKind::Posix, BackendKind::Object] {
        let dir = tmpdir(&format!("sharded_{}", backend.name()));
        let r = complete(
            solve_sharded::<u32>(
                &e,
                &ShardOptions {
                    shards: 4,
                    dir: dir.clone(),
                    backend,
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        assert_eq!(
            baseline.log_score.to_bits(),
            r.log_score.to_bits(),
            "{}: bit-identical to the resident solver",
            backend.name()
        );
        assert_eq!(baseline.network, r.network, "{}", backend.name());
        assert_eq!(baseline.order, r.order, "{}", backend.name());
        results.push(r);
        let _ = std::fs::remove_dir_all(&dir);
    }
    assert_eq!(
        results[0].stats.score_evals, results[1].stats.score_evals,
        "identical work across backends"
    );

    // object checkpoint → object resume
    let dir = tmpdir("object_ckpt");
    let opts = |stop| ShardOptions {
        shards: 2,
        dir: dir.clone(),
        backend: BackendKind::Object,
        stop_after_level: stop,
        ..Default::default()
    };
    match solve_sharded::<u32>(&e, &opts(Some(4))).unwrap() {
        ShardOutcome::Checkpointed { level, .. } => assert_eq!(level, 4),
        ShardOutcome::Complete(_) => panic!("expected a checkpoint"),
    }
    let resumed = complete(
        solve_sharded::<u32>(
            &e,
            &ShardOptions {
                shards: 0, // geometry from the manifest
                dir: dir.clone(),
                backend: BackendKind::Object,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    assert_eq!(baseline.log_score.to_bits(), resumed.log_score.to_bits());
    assert_eq!(baseline.network, resumed.network);
    assert!(
        resumed.stats.resumed_levels >= 5,
        "committed levels reused: {}",
        resumed.stats.resumed_levels
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE 4 acceptance criterion: a p = 12 clustered solve on the
/// object backend — two in-process hosts, with one stale-claim reclaim
/// injected mid-run (a forged dead host's claim plus its garbage staged
/// upload) — produces scores byte-identical to the POSIX-backend cluster
/// and to the plain `LeveledSolver`, with every subset scored exactly
/// once across the cluster.
#[test]
fn p12_clustered_object_solve_with_injected_reclaim_is_bit_identical() {
    let p = 12;
    let d = synth::random(p, 80, 3, &mut bnsl::util::rng::Rng::new(2024));
    let e = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let baseline = LeveledSolver::new(&e).solve();

    // reference: two-host POSIX cluster
    let posix_dir = tmpdir("accept_posix");
    let posix_results: Vec<SolveResult> =
        run_hosts(&e, &posix_dir, BackendKind::Posix, 4, 2, None)
            .into_iter()
            .map(complete)
            .collect();
    for r in &posix_results {
        assert_eq!(baseline.log_score.to_bits(), r.log_score.to_bits());
        assert_eq!(baseline.network, r.network);
    }

    // object cluster, phase 1: both hosts checkpoint at level 3
    let dir = tmpdir("accept_object");
    for outcome in run_hosts(&e, &dir, BackendKind::Object, 4, 2, Some(3)) {
        match outcome {
            ShardOutcome::Checkpointed { level, .. } => assert_eq!(level, 3),
            ShardOutcome::Complete(_) => panic!("expected a checkpoint"),
        }
    }
    // inject the reclaim: a claim whose owner (host 9) died an hour ago,
    // plus the partial staged upload it left behind
    let store = ObjectBackend::with_faults(&dir, ObjectFaults::default());
    store
        .create_exclusive(
            "claim-04-0001.json",
            b"{\"format\": 1, \"level\": 4, \"shard\": 1, \"host\": 9, \
              \"pid\": 1, \"heartbeat_secs\": 2}",
        )
        .unwrap();
    store.backdate("claim-04-0001.json", Duration::from_secs(3600));
    store
        .put_doc(
            "level_04_shard_0001.qr.host-0009-1-0",
            b"partial garbage from a dead writer",
        )
        .unwrap();

    // phase 2: two hosts finish the run, stealing the forged claim
    let results: Vec<SolveResult> = run_hosts(&e, &dir, BackendKind::Object, 4, 2, None)
        .into_iter()
        .map(complete)
        .collect();
    for (host, r) in results.iter().enumerate() {
        assert_eq!(
            baseline.log_score.to_bits(),
            r.log_score.to_bits(),
            "host {host}: object cluster bit-identical to LeveledSolver"
        );
        assert_eq!(
            posix_results[0].log_score.to_bits(),
            r.log_score.to_bits(),
            "host {host}: object cluster bit-identical to the POSIX cluster"
        );
        assert_eq!(baseline.network, r.network, "host {host}");
        assert_eq!(baseline.order, r.order, "host {host}");
    }
    // exactly-once work across the cluster: only the uncommitted levels
    // were scored, the reclaimed shard exactly once
    let total: u64 = results.iter().map(|r| r.stats.score_evals).sum();
    let expected: u64 = (4..=p as u64).map(|k| binom(p as u64, k)).sum();
    assert_eq!(total, expected, "reclaim did not duplicate work");
    // the forged claim and garbage staged upload are gone
    assert!(!store.exists("claim-04-0001.json").unwrap(), "claim reclaimed");
    assert!(
        !store
            .exists("level_04_shard_0001.qr.host-0009-1-0")
            .unwrap(),
        "staged stray cleaned"
    );
    let leftovers: Vec<String> = store
        .list("claim-")
        .unwrap()
        .into_iter()
        .chain(store.list("finish-").unwrap())
        .collect();
    assert!(leftovers.is_empty(), "ledger cleaned: {leftovers:?}");
    let _ = std::fs::remove_dir_all(&posix_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A run directory is **bound** to the backend that created it: the
/// manifest records the binding, a mismatched resume/join is rejected
/// up front with the flag to use (mixed backends judge liveness by
/// different stamps — mtime vs. heartbeat metadata — so a silent mix
/// would spuriously steal live claims), and the matching resume
/// finishes bit-identically.
#[test]
fn run_directories_are_bound_to_their_backend() {
    let d = synth::random(9, 60, 3, &mut bnsl::util::rng::Rng::new(5));
    let e = NativeEngine::new(&d, ScoreKind::Bic);
    let baseline = LeveledSolver::new(&e).solve();
    let dir = tmpdir("bound");
    let outcome = solve_sharded::<u32>(
        &e,
        &ShardOptions {
            shards: 2,
            dir: dir.clone(),
            backend: BackendKind::Posix,
            stop_after_level: Some(3),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(matches!(outcome, ShardOutcome::Checkpointed { level: 3, .. }));
    // resuming the POSIX run through the object backend is refused
    let err = solve_sharded::<u32>(
        &e,
        &ShardOptions {
            shards: 0,
            dir: dir.clone(),
            backend: BackendKind::Object,
            ..Default::default()
        },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("--backend posix"), "{err}");
    assert!(err.contains("bound"), "{err}");
    // the matching backend resumes and finishes bit-identically
    let r = complete(
        solve_sharded::<u32>(
            &e,
            &ShardOptions {
                shards: 0,
                dir: dir.clone(),
                backend: BackendKind::Posix,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    assert_eq!(baseline.log_score.to_bits(), r.log_score.to_bits());
    assert_eq!(baseline.network, r.network);
    let _ = std::fs::remove_dir_all(&dir);
}

/// CLI wiring: `--backend object` drives the object coordinator end to
/// end and emits a record byte-identical to the POSIX run's; misuse of
/// the flag is rejected up front.
#[test]
fn cli_backend_object_roundtrip_and_validation() {
    let base = tmpdir("cli");
    std::fs::create_dir_all(&base).unwrap();
    let learn = |backend: &str, sub: &str| -> String {
        let out = base.join(format!("net_{backend}.json"));
        bnsl::cli::run(vec![
            "learn".into(),
            "--network".into(),
            "asia".into(),
            "--n".into(),
            "120".into(),
            "--shards".into(),
            "2".into(),
            "--backend".into(),
            backend.into(),
            "--shard-dir".into(),
            base.join(sub).to_string_lossy().into_owned(),
            "--out".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        std::fs::read_to_string(&out).unwrap()
    };
    let posix_out = learn("posix", "run_posix");
    let object_out = learn("object", "run_object");
    let score_line = |text: &str| -> String {
        text.lines()
            .find(|l| l.contains("\"log_score\""))
            .expect("log_score line")
            .trim()
            .to_string()
    };
    assert_eq!(
        score_line(&posix_out),
        score_line(&object_out),
        "identical score record across backends"
    );
    assert!(
        base.join("run_object").join("manifest.json").exists(),
        "object run mirrors the file layout"
    );

    // --backend without the sharded coordinator is rejected
    let err = bnsl::cli::run(vec![
        "learn".into(),
        "--network".into(),
        "asia".into(),
        "--n".into(),
        "40".into(),
        "--backend".into(),
        "object".into(),
    ])
    .unwrap_err()
    .to_string();
    assert!(err.contains("--backend"), "{err}");
    assert!(err.contains("--shards"), "{err}");
    // unknown backends are rejected by name
    let err = bnsl::cli::run(vec![
        "learn".into(),
        "--network".into(),
        "asia".into(),
        "--n".into(),
        "40".into(),
        "--shards".into(),
        "2".into(),
        "--backend".into(),
        "s3".into(),
        "--shard-dir".into(),
        base.join("bad").to_string_lossy().into_owned(),
    ])
    .unwrap_err()
    .to_string();
    assert!(err.contains("posix"), "{err}");
    assert!(err.contains("s3"), "{err}");
    let _ = std::fs::remove_dir_all(&base);
}
