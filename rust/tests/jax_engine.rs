//! Cross-layer integration: rust loads the AOT JAX/Pallas artifact via
//! PJRT and must agree with the native f64 engine.
//!
//! Requires `make artifacts` (skipped with a loud message otherwise).

use bnsl::data::synth;
use bnsl::engine::{JaxEngine, NativeEngine, ScoreEngine};
use bnsl::score::ScoreKind;
use bnsl::solver::{LeveledSolver, SilanderSolver};
use std::path::{Path, PathBuf};

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let has_artifacts = std::fs::read_dir(&dir)
        .map(|entries| {
            entries
                .flatten()
                .any(|e| e.file_name().to_string_lossy().ends_with(".hlo.txt"))
        })
        .unwrap_or(false);
    if has_artifacts {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts in {dir:?}; run `make artifacts`");
        None
    }
}

#[test]
fn jax_engine_matches_native_on_random_subsets() {
    let Some(dir) = artifact_dir() else { return };
    let d = synth::uniform(8, 120, &[2, 3, 2, 4, 2, 3, 2, 2], 42);
    let native = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let jax = JaxEngine::new(&d, ScoreKind::Jeffreys, &dir).expect("load artifact");

    let mut ns = native.scorer();
    let mut js = jax.scorer();
    let masks: Vec<u32> = (0u32..256).collect();
    let mut nv = Vec::new();
    let mut jv = Vec::new();
    ns.log_q_batch(&masks, &mut nv);
    js.log_q_batch(&masks, &mut jv);
    for (i, &mask) in masks.iter().enumerate() {
        let scale = nv[i].abs().max(1.0);
        assert!(
            (nv[i] - jv[i]).abs() / scale < 1e-4,
            "mask {mask:#b}: native {} vs jax {}",
            nv[i],
            jv[i]
        );
    }
    assert!(jax.executions() >= 1, "PJRT actually executed");
}

#[test]
fn jax_engine_handles_empty_and_full_masks() {
    let Some(dir) = artifact_dir() else { return };
    let d = synth::binary(6, 200, 7);
    let jax = JaxEngine::new(&d, ScoreKind::Jeffreys, &dir).expect("load artifact");
    let mut s = jax.scorer();
    let empty = s.log_q(0);
    assert!(empty.abs() < 1e-4, "log Q(∅) = 0, got {empty}");
    let full = s.log_q((1 << 6) - 1);
    assert!(full < 0.0);
}

#[test]
fn leveled_solver_over_jax_engine_matches_native_solvers() {
    let Some(dir) = artifact_dir() else { return };
    // p small: interpret-mode Pallas is a correctness vehicle, not fast
    let d = synth::uniform(6, 80, &[2, 2, 3, 2, 2, 2], 11);
    let native = NativeEngine::new(&d, ScoreKind::Jeffreys);
    let exact = LeveledSolver::new(&native).solve();

    let jax = JaxEngine::new(&d, ScoreKind::Jeffreys, &dir).expect("load artifact");
    let approx = LeveledSolver::new_local(&jax).solve();

    let scale = exact.log_score.abs().max(1.0);
    assert!(
        (exact.log_score - approx.log_score).abs() / scale < 1e-3,
        "native {} vs jax {}",
        exact.log_score,
        approx.log_score
    );
    // f32 scoring may flip exact ties, but on random data the optimum is
    // unique: demand the same Markov equivalence class.
    assert_eq!(
        bnsl::bn::cpdag_of(&exact.network),
        bnsl::bn::cpdag_of(&approx.network),
        "same equivalence class"
    );

    // and silander over jax agrees with leveled over jax bit-for-bit
    let silander = SilanderSolver::new(&jax).solve();
    assert_eq!(silander.log_score.to_bits(), approx.log_score.to_bits());
}

#[test]
fn jax_engine_rejects_non_jeffreys_scores() {
    let Some(dir) = artifact_dir() else { return };
    let d = synth::binary(4, 50, 1);
    assert!(JaxEngine::new(&d, ScoreKind::Bic, &dir).is_err());
    assert!(JaxEngine::new(&d, ScoreKind::Bdeu { ess: 1.0 }, &dir).is_err());
}

#[test]
fn jax_engine_rejects_oversized_datasets() {
    let Some(dir) = artifact_dir() else { return };
    // artifacts cover n ≤ 256
    let d = synth::binary(4, 300, 1);
    assert!(JaxEngine::new(&d, ScoreKind::Jeffreys, &dir).is_err());
}
