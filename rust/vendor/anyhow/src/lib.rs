//! Minimal, API-compatible subset of the `anyhow` crate, vendored so the
//! repository builds fully offline. Implements the pieces `bnsl` uses:
//!
//! * [`Error`] — an opaque error value built from any `std::error::Error`
//!   or a formatted message, carrying a human-readable context chain.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`anyhow!`] / [`bail!`] — message construction / early return.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, mirroring the upstream trait shape.
//!
//! Like upstream `anyhow`, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// Opaque error: a display message plus an optional chain of context
/// strings, rendered outermost-first like upstream anyhow.
pub struct Error {
    /// Context chain, most recent (outermost) first; the original cause
    /// message is the last element.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (without the cause chain).
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full context chain, `outer: inner: cause`.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context lines.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Context extension for `Result` and `Option`, mirroring upstream.
pub trait Context<T, E>: Sized {
    /// Wrap the error with a context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::IntoShimError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_shim_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_shim_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

mod ext {
    //! Coherence helper (same trick as upstream anyhow): a local trait
    //! implemented both for every `std::error::Error` type and for
    //! [`crate::Error`] itself, so `.context(..)` works on either.
    pub trait IntoShimError {
        fn into_shim_error(self) -> crate::Error;
    }

    impl<E> IntoShimError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_shim_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoShimError for crate::Error {
        fn into_shim_error(self) -> crate::Error {
            self
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing file"));
    }

    #[test]
    fn context_chains_render_alternate() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u8> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
        let e2 = anyhow!("bad value {}", 42);
        assert_eq!(format!("{e2}"), "bad value 42");
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_shim_result_works() {
        fn inner() -> Result<()> {
            Err(anyhow!("cause"))
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: cause");
    }
}
